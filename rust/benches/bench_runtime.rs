//! Bench: PJRT runtime layer — artifact compile time, literal round
//! trips, host init, state clone; the fixed costs around every train
//! step. Feeds EXPERIMENTS.md §Perf (L3).

use mosa::runtime::engine::{lit_i32, Engine};
use mosa::runtime::{Manifest, TrainState};
use mosa::util::stats::{bench, report, time_once};

fn main() {
    println!("== bench_runtime ==");
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping runtime bench (no artifacts): {e}");
            return;
        }
    };
    let v = manifest.variant("micro_mosa_r8").expect("core set");
    let mut engine = Engine::cpu().unwrap();

    let (_, dur) = time_once(|| engine.load_program(&manifest, v, "score").unwrap());
    println!("xla_compile score: {:.2}s", dur.as_secs_f64());
    let (_, dur) = time_once(|| engine.load_program(&manifest, v, "train").unwrap());
    println!("xla_compile train: {:.2}s", dur.as_secs_f64());
    let (_, dur) = time_once(|| engine.load_program(&manifest, v, "train").unwrap());
    println!("xla_compile train (cached): {:.6}s", dur.as_secs_f64());

    let s = bench(2, 20, || {
        std::hint::black_box(TrainState::init_host(v, 0).unwrap());
    });
    report("host_init (118 leaves, 2.3 MB params)", &s);

    let state = TrainState::init_host(v, 0).unwrap();
    let s = bench(2, 50, || {
        let c: Vec<xla::Literal> = state.leaves.iter().cloned().collect();
        std::hint::black_box(c);
    });
    report("state_clone (per-step input copy)", &s);

    let b = v.batch;
    let t1 = v.config.seq_len + 1;
    let tokens: Vec<i32> = (0..b * t1).map(|i| (i % 500) as i32).collect();
    let s = bench(10, 200, || {
        std::hint::black_box(lit_i32(&tokens, &[b, t1]).unwrap());
    });
    report("batch literal build 8x129", &s);

    // score round-trip: inputs upload + execute + result download
    let untupled = v.program("score").unwrap().untupled;
    let exe_ptr = manifest.hlo_path(v, "score").unwrap();
    let exe = engine.load(&exe_ptr).unwrap();
    let mut inputs: Vec<xla::Literal> = state.model_leaves(v).to_vec();
    inputs.push(lit_i32(&tokens, &[b, t1]).unwrap());
    let s = bench(2, 15, || {
        std::hint::black_box(Engine::run(exe, &inputs, 1, untupled).unwrap());
    });
    report("score round-trip (fwd only)", &s);
}
