//! Integration tests over the real PJRT runtime + core artifacts.
//!
//! Requires `make artifacts` (the core set). Tests share one Engine via
//! a mutex-free serial pattern: cargo test runs them in threads, so each
//! test builds its own engine; XLA compiles are cached per-engine only,
//! hence the tiny step counts.

use mosa::config::RunConfig;
use mosa::coordinator::{LrSchedule, TrainOptions, Trainer};
use mosa::data::TokenDataset;
use mosa::runtime::{Engine, Manifest, TrainState};
use mosa::util::rng::Pcg;

fn manifest() -> Manifest {
    Manifest::load("artifacts").expect("run `make artifacts` before cargo test")
}

fn rand_source(vocab: usize, seed: u64) -> impl FnMut(usize, usize) -> Vec<i32> {
    let mut rng = Pcg::seeded(seed);
    move |b, t| (0..b * t).map(|_| rng.below(vocab as u32) as i32).collect()
}

fn opts(steps: u64) -> TrainOptions {
    TrainOptions {
        steps,
        schedule: LrSchedule::paper_like(3e-3, 2, steps),
        seed: 0,
        log_every: 0,
        use_chunk: false,
        checkpoint: None,
        eval_every: 0,
        prefetch: true,
        device_resident: true,
    }
}

#[test]
fn host_init_matches_manifest_layout() {
    let m = manifest();
    let v = m.variant("micro_mosa_r8").unwrap();
    let st = TrainState::init_host(v, 0).unwrap();
    assert_eq!(st.leaves.len(), v.n_train_leaves);
    for (lit, spec) in st.leaves.iter().zip(&v.leaves) {
        assert_eq!(lit.element_count(), spec.elems(), "{}", spec.path);
    }
    // ln scales are ones, optimizer moments zeros
    let ln_idx = v.leaves.iter().position(|l| l.path.ends_with("ln1.g")).unwrap();
    let vals = st.leaves[ln_idx].to_vec::<f32>().unwrap();
    assert!(vals.iter().all(|&x| x == 1.0));
    let m_start = v.n_model_leaves();
    let mvals = st.leaves[m_start].to_vec::<f32>().unwrap();
    assert!(mvals.iter().all(|&x| x == 0.0));
}

#[test]
fn train_step_decreases_loss_dense_and_mosa() {
    let m = manifest();
    let mut engine = Engine::cpu().unwrap();
    for name in ["micro_dense", "micro_mosa_r8"] {
        let v = m.variant(name).unwrap();
        let trainer = Trainer::new(&m, v);
        // fixed repeating batch => loss must drop fast
        let mut fixed = {
            let mut rng = Pcg::seeded(3);
            let batch: Vec<i32> =
                (0..v.batch * (v.config.seq_len + 1)).map(|_| rng.below(64) as i32).collect();
            move |b: usize, t: usize| {
                assert_eq!(b * t, batch.len());
                batch.clone()
            }
        };
        let (_, metrics) = trainer.train(&mut engine, &mut fixed, &opts(12)).unwrap();
        let first = metrics.records.first().unwrap().loss;
        let last = metrics.records.last().unwrap().loss;
        assert!(last < first, "{name}: {first} -> {last}");
    }
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let m = manifest();
    let mut engine = Engine::cpu().unwrap();
    let v = m.variant("micro_dense").unwrap();
    let trainer = Trainer::new(&m, v);
    let mut src = rand_source(128, 5);
    let (state, _) = trainer.train(&mut engine, &mut src, &opts(4)).unwrap();

    let path = std::env::temp_dir().join("mosa_it_ckpt.bin");
    state.save(v, &path).unwrap();
    let restored = TrainState::load(v, &path).unwrap();
    assert_eq!(restored.step, state.step);

    // bitwise-identical leaves
    for (a, b) in state.leaves.iter().zip(&restored.leaves) {
        assert_eq!(a.to_vec::<f32>().unwrap(), b.to_vec::<f32>().unwrap());
    }

    // identical eval ppl on identical data
    let ds = TokenDataset::from_ids((0..4000).map(|i| (i % 100) as i32).collect(), 512);
    let mut e1 = mosa::data::SequentialWindows::new(&ds);
    let mut e2 = mosa::data::SequentialWindows::new(&ds);
    let p1 = trainer.evaluate(&mut engine, &mut e1, &state, 2).unwrap();
    let p2 = trainer.evaluate(&mut engine, &mut e2, &restored, 2).unwrap();
    assert_eq!(p1, p2);
}

#[test]
fn chunked_and_per_step_training_agree() {
    let m = manifest();
    let v = m.variant("micro_mosa_r8").unwrap();
    if !v.programs.contains_key("train_chunk") {
        return;
    }
    let mut engine = Engine::cpu().unwrap();
    let trainer = Trainer::new(&m, v);
    let steps = v.program("train_chunk").unwrap().chunk.unwrap() as u64;

    let mut o1 = opts(steps);
    let mut o2 = opts(steps);
    o2.use_chunk = true;

    let mut s1 = rand_source(256, 9);
    let mut s2 = rand_source(256, 9); // same stream
    let (_, m1) = trainer.train(&mut engine, &mut s1, &o1).unwrap();
    let (_, m2) = trainer.train(&mut engine, &mut s2, &o2).unwrap();
    for (a, b) in m1.records.iter().zip(&m2.records) {
        assert!(
            (a.loss - b.loss).abs() < 1e-4,
            "step {}: per-step {} vs chunked {}",
            a.step,
            a.loss,
            b.loss
        );
    }
    let _ = &mut o1;
}

#[test]
fn prefetched_and_inline_training_agree() {
    // the prefetcher must not change the data stream or the math: same
    // source seed => identical loss curves with prefetch on and off
    let m = manifest();
    let v = m.variant("micro_dense").unwrap();
    let mut engine = Engine::cpu().unwrap();
    let trainer = Trainer::new(&m, v);
    let mut o_inline = opts(6);
    o_inline.prefetch = false;
    let o_prefetch = opts(6);
    let mut s1 = rand_source(256, 21);
    let mut s2 = rand_source(256, 21); // same stream
    let (_, m1) = trainer.train(&mut engine, &mut s1, &o_inline).unwrap();
    let (_, m2) = trainer.train(&mut engine, &mut s2, &o_prefetch).unwrap();
    assert_eq!(m1.records.len(), m2.records.len());
    for (a, b) in m1.records.iter().zip(&m2.records) {
        assert_eq!(a.loss, b.loss, "step {}: inline vs prefetched drift", a.step);
    }
}

#[test]
fn score_program_gives_finite_logprobs() {
    let m = manifest();
    let mut engine = Engine::cpu().unwrap();
    let v = m.variant("micro_fixed_r8").unwrap();
    let trainer = Trainer::new(&m, v);
    let state = TrainState::init_host(v, 1).unwrap();
    let ds = TokenDataset::from_ids((0..5000).map(|i| (i % 200) as i32).collect(), 512);
    let mut eval = mosa::data::SequentialWindows::new(&ds);
    let ppl = trainer.evaluate(&mut engine, &mut eval, &state, 2).unwrap();
    // untrained model on vocab 512: ppl near vocab size
    assert!(ppl.is_finite() && ppl > 100.0 && ppl < 2000.0, "ppl={ppl}");
}

#[test]
fn routing_state_updates_during_training() {
    let m = manifest();
    let mut engine = Engine::cpu().unwrap();
    let v = m.variant("micro_routing_r8").unwrap();
    assert!(v.n_state_leaves > 0, "routing variant must carry centroid state");
    let trainer = Trainer::new(&m, v);
    let st0 = TrainState::init_host(v, 0).unwrap();
    let centroid_idx = v.n_params_leaves; // first state leaf
    let before = st0.leaves[centroid_idx].to_vec::<f32>().unwrap();
    let mut src = rand_source(300, 11);
    let (st1, _) = trainer.train(&mut engine, &mut src, &opts(3)).unwrap();
    let after = st1.leaves[centroid_idx].to_vec::<f32>().unwrap();
    assert_ne!(before, after, "EMA centroids did not move");
}

#[test]
fn failure_injection_bad_inputs() {
    let m = manifest();
    // unknown variant
    assert!(m.variant("nope_model").is_err());
    let v = m.variant("micro_dense").unwrap();
    // unknown program
    assert!(v.program("generate").is_err());
    // corrupt checkpoint
    let path = std::env::temp_dir().join("mosa_it_corrupt.bin");
    std::fs::write(&path, b"garbage").unwrap();
    assert!(TrainState::load(v, &path).is_err());
    // truncated checkpoint
    let st = TrainState::init_host(v, 0).unwrap();
    let good = std::env::temp_dir().join("mosa_it_trunc.bin");
    st.save(v, &good).unwrap();
    let bytes = std::fs::read(&good).unwrap();
    std::fs::write(&good, &bytes[..bytes.len() / 2]).unwrap();
    assert!(TrainState::load(v, &good).is_err());
}

// -- decode path (artifact-gated like everything above; pre-decode
// artifacts simply skip via the programs check) -------------------------

#[test]
fn decode_prefill_matches_score_program() {
    // teacher-forcing anchor, Rust side: the prefill program's logprobs
    // must equal the score program's on the same weights and tokens for
    // every decode-capable variant (exact by construction — prefill
    // lowers the same forward; see python/tests/test_decode.py for the
    // per-step decode equivalence at tolerance 1e-4).
    let m = manifest();
    let mut engine = Engine::cpu().unwrap();
    for name in ["micro_dense", "micro_mosa_r8"] {
        let v = m.variant(name).unwrap();
        if !v.programs.contains_key("prefill") {
            continue; // pre-decode or partially rebuilt artifacts
        }
        let (b, t) = (v.batch, v.config.seq_len);
        let state = TrainState::init_host(v, 2).unwrap();
        let mut rng = Pcg::seeded(31);
        let tokens: Vec<i32> = (0..b * (t + 1)).map(|_| rng.below(v.config.vocab as u32) as i32).collect();
        // score on [b, t+1]
        let score_spec = v.program("score").unwrap();
        let batch_lit = mosa::runtime::engine::lit_i32(&tokens, &[b, t + 1]).unwrap();
        let mut inputs: Vec<&xla::Literal> = state.model_leaves(v).iter().collect();
        inputs.push(&batch_lit);
        let exe = engine.load_program(&m, v, "score").unwrap();
        let outs = Engine::run(exe, &inputs, 1, score_spec.untupled).unwrap();
        let score_lp = outs[0].to_vec::<f32>().unwrap(); // [b, t]
        // prefill on the first t tokens of each row
        let mut session =
            mosa::decode::DecodeSession::from_state(&m, v, "decode_step", state, true).unwrap();
        let prompt: Vec<i32> = (0..b).flat_map(|i| tokens[i * (t + 1)..i * (t + 1) + t].to_vec()).collect();
        let plen = vec![t as i32; b];
        let (lp_lit, last) = session.prefill(&mut engine, &prompt, &plen).unwrap();
        let lp = lp_lit.to_vec::<f32>().unwrap(); // [b, t-1]
        for i in 0..b {
            for j in 0..t - 1 {
                let a = score_lp[i * t + j];
                let p = lp[i * (t - 1) + j];
                assert!((a - p).abs() < 1e-4, "{name} [{i},{j}]: score {a} vs prefill {p}");
            }
        }
        let last_v = last.to_vec::<f32>().unwrap();
        assert_eq!(last_v.len(), b * v.config.vocab);
        assert!(last_v.iter().all(|x| x.is_finite()));
    }
}

#[test]
fn decode_cache_bytes_match_accounting_at_runtime() {
    let m = manifest();
    for name in ["micro_dense", "micro_mosa_r8", "micro_fixed_r8", "micro_routing_r8"] {
        let Ok(v) = m.variant(name) else { continue };
        let Ok(spec) = v.program("decode_step") else { continue };
        let state = TrainState::init_host(v, 0).unwrap();
        let session = mosa::decode::DecodeSession::from_state(&m, v, "decode_step", state, true).unwrap();
        let cap = spec.capacity.unwrap();
        assert_eq!(
            session.cache_payload_bytes_per_seq,
            mosa::kvcache::kv_bytes_total(&v.config, cap),
            "{name}: manifest cache layout drifted from the accounting"
        );
        // the manifest layout must also agree with the Rust mirror
        let mirror = mosa::decode::cache_layout(&v.config, spec.batch.unwrap(), cap);
        let mirror_kv = mosa::decode::KvCacheBuffers::alloc(&mirror, spec.batch.unwrap()).unwrap();
        assert_eq!(session.cache_total_bytes, mirror_kv.total_bytes(), "{name}");
    }
}

#[test]
fn generate_serves_more_requests_than_slots() {
    let m = manifest();
    let v = m.variant("micro_mosa_r8").unwrap();
    if !v.programs.contains_key("decode_step") {
        return; // pre-decode artifacts
    }
    let mut engine = Engine::cpu().unwrap();
    let state = TrainState::init_host(v, 4).unwrap();
    let slots = v.program("decode_step").unwrap().batch.unwrap_or(v.batch);
    let n_req = slots + 2; // forces at least one admission wave after retirement
    let requests: Vec<mosa::decode::SeqRequest> = (0..n_req as u64)
        .map(|id| mosa::decode::SeqRequest {
            id,
            prompt: vec![1, 2, 3, (id % 7) as i32],
            max_new: 3,
        })
        .collect();
    let opts = mosa::decode::GenerateOptions {
        max_new: 3,
        policy: mosa::decode::SamplePolicy::Greedy,
        seed: 9,
        eos: None,
        use_prefill: true,
        device_resident: true,
        device_sample: true,
        use_paged: true,
    };
    let finished = mosa::decode::generate(&mut engine, &m, v, state, requests, &opts).unwrap();
    assert_eq!(finished.len(), n_req);
    let mut ids: Vec<u64> = finished.iter().map(|f| f.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n_req as u64).collect::<Vec<_>>());
    for f in &finished {
        assert_eq!(f.generated.len(), 3, "seq {} retired early", f.id);
        assert!(f.generated.iter().all(|&t| (0..v.config.vocab as i32).contains(&t)));
    }
}

#[test]
fn decode_device_and_host_paths_agree() {
    // the device-resident cache and the host round-trip cache must be the
    // same computation: identical greedy outputs on identical inputs
    let m = manifest();
    let v = m.variant("micro_dense").unwrap();
    if !v.programs.contains_key("decode_step") {
        return;
    }
    let mut engine = Engine::cpu().unwrap();
    let mut out = Vec::new();
    for resident in [true, false] {
        let state = TrainState::init_host(v, 6).unwrap();
        let mut session =
            mosa::decode::DecodeSession::from_state(&m, v, "decode_step", state, resident).unwrap();
        let b = session.batch;
        let mut logits_trace = Vec::new();
        let mut reset = vec![1i32; b];
        for s in 0..4 {
            let toks: Vec<i32> = (0..b).map(|i| ((i + s) % 50) as i32).collect();
            let pos = vec![s as i32; b];
            let lit = session.step(&mut engine, &toks, &pos, &reset).unwrap();
            logits_trace.push(lit.to_vec::<f32>().unwrap());
            reset.iter_mut().for_each(|r| *r = 0);
        }
        out.push(logits_trace);
    }
    for (a, b) in out[0].iter().zip(&out[1]) {
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-5, "device vs host drift: {x} vs {y}");
        }
    }
}

// -- zero-copy stepping: donation round-trips + in-graph sampling parity --

#[test]
fn donated_resident_train_matches_copying_path() {
    // the donated resident path (state stepped in place on device) must
    // be the same computation as the copying literal path (donation
    // stripped at compile): bit-identical losses on the same stream
    let m = manifest();
    let v = m.variant("micro_mosa_r8").unwrap();
    if v.program("train").unwrap().donated.is_none() {
        return; // pre-donation artifacts
    }
    let mut curves = Vec::new();
    for (donate, resident) in [(true, true), (false, false)] {
        let mut engine = Engine::cpu().unwrap();
        engine.donate = donate;
        let trainer = Trainer::new(&m, v);
        let mut o = opts(5);
        o.device_resident = resident;
        let mut src = rand_source(256, 77);
        let (state, metrics) = trainer.train(&mut engine, &mut src, &o).unwrap();
        assert_eq!(state.step, 5);
        curves.push(metrics.records.iter().map(|r| r.loss).collect::<Vec<_>>());
    }
    assert_eq!(curves[0], curves[1], "donated resident vs copying loss drift");
}

#[test]
fn donated_decode_matches_copying_decode() {
    // same tokens through the donated resident cache and through the
    // donation-stripped host round-trip cache: identical logits
    let m = manifest();
    let v = m.variant("micro_mosa_r8").unwrap();
    if !v.programs.contains_key("decode_step")
        || v.program("decode_step").unwrap().donated.is_none()
    {
        return;
    }
    let mut traces = Vec::new();
    for (donate, resident) in [(true, true), (false, false)] {
        let mut engine = Engine::cpu().unwrap();
        engine.donate = donate;
        let state = TrainState::init_host(v, 11).unwrap();
        let mut session =
            mosa::decode::DecodeSession::from_state(&m, v, "decode_step", state, resident).unwrap();
        let b = session.batch;
        let mut reset = vec![1i32; b];
        let mut trace = Vec::new();
        for s in 0..5 {
            let toks: Vec<i32> = (0..b).map(|i| ((3 * i + s) % 40) as i32).collect();
            let pos = vec![s as i32; b];
            let lit = session.step(&mut engine, &toks, &pos, &reset).unwrap();
            trace.push(lit.to_vec::<f32>().unwrap());
            reset.iter_mut().for_each(|r| *r = 0);
        }
        assert!(session.device_resident == resident, "unexpected demotion");
        traces.push(trace);
    }
    assert_eq!(traces[0], traces[1], "donated vs copying decode drift");
}

#[test]
fn in_graph_sampling_matches_host_sampler() {
    // the ISSUE parity acceptance: device-side sampling and the host
    // `sample_row_u` must produce identical ids given the same uniforms,
    // greedy and top-k, at batch > 1
    use mosa::decode::{sample_row_u, SamplePolicy, SampleScratch};
    let m = manifest();
    let v = m.variant("micro_mosa_r8").unwrap();
    if !v.programs.contains_key("decode_step_sample") {
        return; // pre-sampling artifacts
    }
    let mut engine = Engine::cpu().unwrap();
    let vocab = v.config.vocab;
    for policy in [SamplePolicy::Greedy, SamplePolicy::TopK { k: 6, temperature: 0.85 }] {
        let (temp, k) = policy.temp_k();
        let s1 = TrainState::init_host(v, 13).unwrap();
        let s2 = TrainState::init_host(v, 13).unwrap();
        let mut dev =
            mosa::decode::DecodeSession::from_state(&m, v, "decode_step", s1, true).unwrap();
        let mut host =
            mosa::decode::DecodeSession::from_state(&m, v, "decode_step", s2, true).unwrap();
        assert!(dev.sample_k.unwrap() >= k, "policy k exceeds the lowered sampler width");
        let b = dev.batch;
        assert!(b > 1, "parity must cover batch > 1");
        let mut rng = Pcg::seeded(99);
        let mut scratch = SampleScratch::default();
        let mut reset = vec![1i32; b];
        for s in 0..6 {
            // identical teacher-forced streams keep both caches in lockstep
            let toks: Vec<i32> = (0..b).map(|i| ((7 * i + 3 * s) % 50) as i32).collect();
            let pos = vec![s as i32; b];
            let uniforms: Vec<f32> = (0..b).map(|_| rng.f32()).collect();
            let sampled = dev
                .step_sample(&mut engine, &toks, &pos, &reset, &uniforms, temp, k, true)
                .unwrap();
            let logits_lit = host.step(&mut engine, &toks, &pos, &reset).unwrap();
            let logits = logits_lit.to_vec::<f32>().unwrap();
            let want: Vec<i32> = (0..b)
                .map(|i| {
                    sample_row_u(
                        &logits[i * vocab..(i + 1) * vocab],
                        &policy,
                        uniforms[i],
                        &mut scratch,
                    )
                })
                .collect();
            assert_eq!(sampled.ids, want, "policy {policy:?} step {s}");
            // the logging tail: k_max per row, values sorted descending,
            // the sampled id inside the top-k support
            let (vals, ids) = sampled.topk.expect("topk tail requested");
            let kmax = dev.sample_k.unwrap();
            assert_eq!(vals.len(), b * kmax);
            assert_eq!(ids.len(), b * kmax);
            for i in 0..b {
                let row = &vals[i * kmax..(i + 1) * kmax];
                assert!(row.windows(2).all(|w| w[0] >= w[1]), "topk not sorted");
                let support = &ids[i * kmax..i * kmax + k];
                assert!(support.contains(&sampled.ids[i]));
            }
            reset.iter_mut().for_each(|r| *r = 0);
        }
    }
}

// -- paged KV-cache serving: the differential paged-vs-contiguous tests --

#[test]
fn paged_decode_bit_identical_to_contiguous() {
    // the tentpole acceptance: prefill + teacher-forced decode through
    // the paged programs produces BIT-IDENTICAL logits to the contiguous
    // twin on the rebuilt micro artifacts, for every decode-capable
    // head kind in the manifest
    let m = manifest();
    let mut engine = Engine::cpu().unwrap();
    for name in ["micro_dense", "micro_mosa_r8", "micro_fixed_r8", "micro_routing_r8"] {
        let Ok(v) = m.variant(name) else { continue };
        if !v.programs.contains_key("decode_step_paged") {
            continue; // pre-paging artifacts
        }
        let mut traces: Vec<Vec<Vec<f32>>> = Vec::new();
        for step_name in ["decode_step", "decode_step_paged"] {
            let state = TrainState::init_host(v, 21).unwrap();
            let mut session =
                mosa::decode::DecodeSession::from_state(&m, v, step_name, state, true).unwrap();
            assert_eq!(session.paged, step_name.ends_with("paged"));
            let b = session.batch;
            let p = v.program("prefill").unwrap().prompt_len.unwrap();
            let mut rng = Pcg::seeded(17);
            let tokens: Vec<i32> =
                (0..b * p).map(|_| rng.below(v.config.vocab as u32) as i32).collect();
            let plen = vec![(p / 2) as i32; b];
            let (lp, last) = session.prefill(&mut engine, &tokens, &plen).unwrap();
            let mut trace = vec![lp.to_vec::<f32>().unwrap(), last.to_vec::<f32>().unwrap()];
            let mut reset = vec![0i32; b];
            for s in 0..4 {
                let toks: Vec<i32> = (0..b).map(|i| ((5 * i + s) % 60) as i32).collect();
                let pos = vec![(p / 2 + s) as i32; b];
                let lit = session.step(&mut engine, &toks, &pos, &reset).unwrap();
                trace.push(lit.to_vec::<f32>().unwrap());
                reset.iter_mut().for_each(|r| *r = 0);
            }
            traces.push(trace);
        }
        assert_eq!(traces[0], traces[1], "{name}: paged vs contiguous logits drift");
    }
}

#[test]
fn paged_session_resident_bytes_below_contiguous() {
    // the overcommitted pools must actually shrink the device-resident
    // cache: >= 2x below the contiguous layout at the serving capacity
    // (the BENCH_decode `paged` arm reports the same numbers)
    let m = manifest();
    for name in ["micro_dense", "micro_mosa_r8"] {
        let Ok(v) = m.variant(name) else { continue };
        if !v.programs.contains_key("decode_step_paged") {
            continue;
        }
        let s1 = TrainState::init_host(v, 0).unwrap();
        let s2 = TrainState::init_host(v, 0).unwrap();
        let paged = mosa::decode::DecodeSession::from_state(&m, v, "decode_step_paged", s1, true)
            .unwrap();
        let contiguous =
            mosa::decode::DecodeSession::from_state(&m, v, "decode_step", s2, true).unwrap();
        // logical per-sequence accounting agrees across layouts
        assert_eq!(
            paged.cache_payload_bytes_per_seq, contiguous.cache_payload_bytes_per_seq,
            "{name}: logical accounting drift"
        );
        assert_eq!(
            contiguous.cache_payload_bytes_per_seq,
            mosa::kvcache::kv_bytes_total(&v.config, contiguous.capacity),
            "{name}"
        );
        assert!(
            paged.cache_resident_payload_bytes * 2 <= contiguous.cache_resident_payload_bytes,
            "{name}: paged resident {} vs contiguous {} — overcommit not effective",
            paged.cache_resident_payload_bytes,
            contiguous.cache_resident_payload_bytes
        );
    }
}

#[test]
fn paged_generate_with_forced_eviction_matches_contiguous() {
    // the evict-and-readmit acceptance: serve enough long sequences that
    // the overcommitted pool MUST park and replay some of them; greedy
    // streams are deterministic in the context, so every finished
    // sequence must match the contiguous run token-for-token
    let m = manifest();
    let v = m.variant("micro_mosa_r8").unwrap();
    if !v.programs.contains_key("decode_step_paged") {
        return;
    }
    let slots = v.program("decode_step_paged").unwrap().batch.unwrap();
    let prompt_len = 24;
    // enough new tokens that slots × pages(prompt+max_new) overflows the
    // 0.25-provisioned lazy pools mid-generation
    let pg = v.program("decode_step_paged").unwrap().pages.as_ref().unwrap();
    let lazy_pool: usize =
        pg.kinds.iter().filter(|k| k.lazy).map(|k| k.pool_pages).min().unwrap();
    // drive every slot ~2 pages past its fair share of the lazy pool
    let max_new = (lazy_pool / slots + 2) * pg.page_size;
    let requests = |n: usize| -> Vec<mosa::decode::SeqRequest> {
        let mut rng = Pcg::seeded(123);
        (0..n as u64)
            .map(|id| mosa::decode::SeqRequest {
                id,
                prompt: (0..prompt_len)
                    .map(|_| rng.below(v.config.vocab as u32) as i32)
                    .collect(),
                max_new,
            })
            .collect()
    };
    let mut runs = Vec::new();
    let mut parked = 0;
    for use_paged in [true, false] {
        let mut engine = Engine::cpu().unwrap();
        let state = TrainState::init_host(v, 33).unwrap();
        let opts = mosa::decode::GenerateOptions {
            max_new,
            policy: mosa::decode::SamplePolicy::Greedy,
            seed: 7,
            eos: None,
            // stream the prompts: with prefill off, every cache (first
            // pass AND post-park replay) is built by pure decode-stepping,
            // so parking is bitwise stream-invariant and the cross-arm
            // equality below is exact. (Prefill-built caches only agree
            // with stepped ones to ~1e-4 — near-tie greedy picks could
            // differ after a replay. The prefill serving shape is pinned
            // bitwise by paged_generate_with_prefill_matches_contiguous,
            // where nothing parks.)
            use_prefill: false,
            device_resident: true,
            device_sample: true,
            use_paged,
        };
        let (finished, stats) = mosa::decode::generate_with_stats(
            &mut engine,
            &m,
            v,
            state,
            requests(slots + 2),
            &opts,
        )
        .unwrap();
        assert_eq!(finished.len(), slots + 2);
        assert_eq!(stats.paged, use_paged);
        if use_paged {
            parked = stats.parked;
        }
        let mut by_id: Vec<_> = finished.into_iter().collect();
        by_id.sort_by_key(|f| f.id);
        runs.push(
            by_id
                .into_iter()
                .map(|f| (f.id, f.prompt, f.generated))
                .collect::<Vec<_>>(),
        );
    }
    assert!(
        parked > 0,
        "pool was never under pressure — the eviction path went unexercised \
         (grow max_new or shrink pool_frac)"
    );
    assert_eq!(runs[0], runs[1], "paged(+evictions) vs contiguous streams drift");
}

#[test]
fn paged_generate_with_prefill_matches_contiguous() {
    // the default serving shape (prefill wave + decode) through the
    // paged programs: page mapping runs via ContinuousBatcher::prefill_plan
    // and the streams must equal the contiguous arm token-for-token
    // (no eviction at this load, so both arms are bitwise comparable)
    let m = manifest();
    let v = m.variant("micro_mosa_r8").unwrap();
    if !v.programs.contains_key("decode_step_paged") {
        return;
    }
    let slots = v.program("decode_step_paged").unwrap().batch.unwrap();
    let mut runs = Vec::new();
    for use_paged in [true, false] {
        let mut engine = Engine::cpu().unwrap();
        let state = TrainState::init_host(v, 51).unwrap();
        let opts = mosa::decode::GenerateOptions {
            max_new: 6,
            policy: mosa::decode::SamplePolicy::TopK { k: 4, temperature: 0.9 },
            seed: 3,
            eos: None,
            use_prefill: true,
            device_resident: true,
            device_sample: true,
            use_paged,
        };
        let requests: Vec<mosa::decode::SeqRequest> = (0..(slots + 1) as u64)
            .map(|id| mosa::decode::SeqRequest {
                id,
                prompt: vec![3, 1, 4, 1, 5, (id % 9) as i32],
                max_new: 6,
            })
            .collect();
        let (finished, stats) =
            mosa::decode::generate_with_stats(&mut engine, &m, v, state, requests, &opts).unwrap();
        assert_eq!(finished.len(), slots + 1);
        assert_eq!(stats.parked, 0, "this load must not evict");
        let mut by_id: Vec<_> = finished;
        by_id.sort_by_key(|f| f.id);
        runs.push(by_id.into_iter().map(|f| (f.id, f.generated)).collect::<Vec<_>>());
    }
    assert_eq!(runs[0], runs[1], "paged-with-prefill vs contiguous streams drift");
}

#[test]
fn manifest_flops_match_rust_flops_module() {
    use mosa::flops::{model_forward, SparseKind};
    let m = manifest();
    for v in m.variants.values() {
        let c = &v.config;
        let kind = SparseKind::parse(&c.sparse_kind).unwrap();
        let f = model_forward(
            c.n_layers as u64,
            c.d_model as u64,
            c.d_head as u64,
            c.d_ff as u64,
            c.seq_len as u64,
            c.n_dense as u64,
            c.window as u64,
            c.n_sparse as u64,
            kind,
            c.k_sel as u64,
        );
        assert_eq!(f, v.flops_fwd, "{} (python/rust FLOP mirror drift)", v.name);
    }
}

// -- request lifecycle: the serve layer over the real PJRT session --------

/// Serve a workload through `serve::Server` + `SessionDispatcher` and
/// return the per-request greedy streams, keyed by id.
fn serve_streams(
    m: &Manifest,
    v: &mosa::runtime::Variant,
    step_name: &str,
    plan: mosa::serve::FaultPlan,
    n_req: usize,
) -> (mosa::serve::ServeReport, Vec<(u64, Vec<i32>)>) {
    let mut engine = Engine::cpu().unwrap();
    let state = TrainState::init_host(v, 11).unwrap();
    let session = mosa::decode::DecodeSession::from_state(m, v, step_name, state, true).unwrap();
    let dispatcher = mosa::serve::SessionDispatcher::new(
        session,
        &mut engine,
        mosa::decode::SamplePolicy::Greedy,
        true,
    );
    let requests: Vec<mosa::serve::ServeRequest> = (0..n_req as u64)
        .map(|id| mosa::serve::ServeRequest::new(id, vec![1, 2, 3, (id % 7) as i32], 3))
        .collect();
    let report = mosa::serve::serve(dispatcher, mosa::serve::ServeConfig::default(), plan, requests);
    let mut streams: Vec<(u64, Vec<i32>)> =
        report.results.iter().map(|r| (r.id, r.generated.clone())).collect();
    streams.sort_unstable_by_key(|(id, _)| *id);
    (report, streams)
}

#[test]
fn serve_layer_matches_generate_streams() {
    // the lifecycle layer adds queueing/guards/retries around the same
    // batcher `generate` drives — on a fault-free greedy run the streams
    // must be bit-identical to stepwise generate (no prefill either side)
    let m = manifest();
    let v = m.variant("micro_mosa_r8").unwrap();
    if !v.programs.contains_key("decode_step") {
        return; // pre-decode artifacts
    }
    let slots = v.program("decode_step").unwrap().batch.unwrap_or(v.batch);
    let n_req = slots + 2; // at least one admission wave after retirement
    let (report, served) =
        serve_streams(&m, v, "decode_step", mosa::serve::FaultPlan::none(), n_req);
    assert!(report.fatal.is_none(), "fatal: {:?}", report.fatal);
    assert_eq!(report.count(mosa::serve::Outcome::Completed), n_req);

    let mut engine = Engine::cpu().unwrap();
    let state = TrainState::init_host(v, 11).unwrap();
    let requests: Vec<mosa::decode::SeqRequest> = (0..n_req as u64)
        .map(|id| mosa::decode::SeqRequest {
            id,
            prompt: vec![1, 2, 3, (id % 7) as i32],
            max_new: 3,
        })
        .collect();
    let opts = mosa::decode::GenerateOptions {
        max_new: 3,
        policy: mosa::decode::SamplePolicy::Greedy,
        seed: 9,
        eos: None,
        use_prefill: false, // the serve layer steps prompts token-wise
        device_resident: true,
        device_sample: true,
        use_paged: false,
    };
    let finished = mosa::decode::generate(&mut engine, &m, v, state, requests, &opts).unwrap();
    let mut expect: Vec<(u64, Vec<i32>)> =
        finished.iter().map(|f| (f.id, f.generated.clone())).collect();
    expect.sort_unstable_by_key(|(id, _)| *id);
    assert_eq!(served, expect, "serve layer drifted from generate");
}

#[test]
fn faulted_serve_recovers_and_leaks_no_pages() {
    // inject dispatch failures into the real paged session: the run must
    // recover (not fail), release every pool page, and the surviving
    // greedy streams must match the unfaulted run bit-for-bit
    let m = manifest();
    let v = m.variant("micro_mosa_r8").unwrap();
    if !v.programs.contains_key("decode_step_paged") {
        return; // pre-paging artifacts
    }
    let slots = v.program("decode_step_paged").unwrap().batch.unwrap_or(v.batch);
    let n_req = slots + 2;
    let (clean, clean_streams) =
        serve_streams(&m, v, "decode_step_paged", mosa::serve::FaultPlan::none(), n_req);
    assert!(clean.fatal.is_none());

    let plan = mosa::serve::FaultPlan::parse("fail@1;fail@3").unwrap();
    let mut engine = Engine::cpu().unwrap();
    let state = TrainState::init_host(v, 11).unwrap();
    let session =
        mosa::decode::DecodeSession::from_state(&m, v, "decode_step_paged", state, true).unwrap();
    let table = session.shared_pages().expect("paged session has a pool");
    let dispatcher = mosa::serve::SessionDispatcher::new(
        session,
        &mut engine,
        mosa::decode::SamplePolicy::Greedy,
        true,
    );
    let requests: Vec<mosa::serve::ServeRequest> = (0..n_req as u64)
        .map(|id| mosa::serve::ServeRequest::new(id, vec![1, 2, 3, (id % 7) as i32], 3))
        .collect();
    let report =
        mosa::serve::serve(dispatcher, mosa::serve::ServeConfig::default(), plan, requests);
    assert!(report.fatal.is_none(), "fatal: {:?}", report.fatal);
    assert_eq!(report.count(mosa::serve::Outcome::Completed), n_req);
    assert!(report.stats.dispatch_failures >= 2, "{:?}", report.stats);
    assert!(report.stats.recovered > 0, "{:?}", report.stats);
    assert_eq!(table.pages_free(), table.pool_pages_total(), "pool pages leaked");
    assert!(table.check_conservation());
    let mut streams: Vec<(u64, Vec<i32>)> =
        report.results.iter().map(|r| (r.id, r.generated.clone())).collect();
    streams.sort_unstable_by_key(|(id, _)| *id);
    assert_eq!(streams, clean_streams, "fault recovery corrupted a stream");
}

#[test]
fn corrupt_artifact_classifies_as_fatal() {
    // a garbled HLO text must surface as a typed, fatal ServeError
    // (Compile), not as a retryable dispatch error — and the artifact
    // hook must be the only thing standing between the two runs
    use mosa::serve::fault::{artifact_hook, ArtifactFault, CorruptMode};
    use mosa::serve::ServeError;
    let m = manifest();
    let v = m.variant("micro_dense").unwrap();
    if !v.programs.contains_key("decode_step") {
        return;
    }
    let mut engine = Engine::cpu().unwrap();
    engine.set_artifact_hook(Some(Box::new(artifact_hook(vec![ArtifactFault {
        nth_read: 0,
        mode: CorruptMode::Garble,
    }]))));
    let err = engine
        .load_program(&m, v, "decode_step")
        .err()
        .expect("garbled artifact must not compile");
    let typed = ServeError::of(&err).expect("typed error in the chain");
    assert!(typed.fatal(), "corrupt artifact classified transient: {typed}");
    assert!(!ServeError::is_transient(&err));
    // same engine, hook cleared: the untouched artifact compiles fine
    engine.set_artifact_hook(None);
    engine.load_program(&m, v, "decode_step").unwrap();
}
