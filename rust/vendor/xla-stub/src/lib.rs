//! Host-side stub of the `xla` crate surface this repo assumes (see the
//! per-PR notes in CHANGES.md: `PjRtClient::cpu` / `compile` /
//! `buffer_from_host_literal`, `PjRtLoadedExecutable::execute{,_b}`,
//! `HloModuleProto::from_text_file`, and the `Literal` host API).
//!
//! Design rule: everything that can be done on the host without a PJRT
//! runtime *works* (literal construction, reshape, element access,
//! tuple decomposition), so unit tests and the mock-backed serving /
//! chaos / transport paths run for real. Everything that needs a device
//! or the XLA compiler returns `Error::Stub`, which callers already
//! treat as "artifacts unavailable" — the same graceful degradation as
//! a container without cargo. Replace the path dependency with real
//! xla-rs bindings to light up device execution; no call site changes.

use std::fmt;
use std::path::Path;

/// Error type matching how the repo consumes xla errors: `?` into
/// `anyhow::Error` (requires `std::error::Error + Send + Sync`).
#[derive(Debug, Clone)]
pub enum Error {
    /// The operation needs the real XLA runtime; this build carries the
    /// vendored host stub.
    Stub(&'static str),
    /// Host-side misuse caught by the stub (shape/dtype mismatches).
    Host(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Stub(what) => write!(
                f,
                "xla stub: {what} requires the real xla-rs bindings + a PJRT \
                 plugin (this build vendors rust/vendor/xla-stub; see rust/Cargo.toml)"
            ),
            Error::Host(why) => write!(f, "xla stub: {why}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtype of a literal. Only the types the repo stores in
/// literals are represented.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S8,
    S32,
    S64,
    U8,
}

impl ElementType {
    fn size_bytes(self) -> usize {
        match self {
            ElementType::S8 | ElementType::U8 => 1,
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::F64 | ElementType::S64 => 8,
        }
    }
}

/// Sealed-style conversion trait mirroring xla-rs `NativeType`: the
/// scalar types `Literal::vec1` / `scalar` / `to_vec` / `copy_raw_to`
/// are generic over.
pub trait NativeType: Copy + Default + 'static {
    const TY: ElementType;
    fn to_le(self) -> Vec<u8>;
    fn from_le(b: &[u8]) -> Self;
}

macro_rules! native {
    ($t:ty, $ty:expr) => {
        impl NativeType for $t {
            const TY: ElementType = $ty;
            fn to_le(self) -> Vec<u8> {
                self.to_le_bytes().to_vec()
            }
            fn from_le(b: &[u8]) -> Self {
                let mut a = [0u8; std::mem::size_of::<$t>()];
                a.copy_from_slice(b);
                <$t>::from_le_bytes(a)
            }
        }
    };
}

native!(f32, ElementType::F32);
native!(f64, ElementType::F64);
native!(i8, ElementType::S8);
native!(i32, ElementType::S32);
native!(i64, ElementType::S64);
native!(u8, ElementType::U8);

/// A host literal: typed little-endian bytes plus a shape. Fully
/// functional in the stub — this is the type the repo's host paths
/// (state init, cache alloc, sampling scratch) actually compute with.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
    /// Tuple literals (only produced by a real runtime's fetch path;
    /// representable so `to_tuple` has a faithful signature).
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let mut bytes = Vec::with_capacity(data.len() * T::TY.size_bytes());
        for x in data {
            bytes.extend_from_slice(&x.to_le());
        }
        Literal { ty: T::TY, dims: vec![data.len() as i64], bytes, tuple: None }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal { ty: T::TY, dims: vec![], bytes: x.to_le(), tuple: None }
    }

    /// Same payload, new shape; errors if the element counts differ.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error::Host(format!(
                "reshape {:?} -> {:?}: element count {} != {}",
                self.dims,
                dims,
                self.element_count(),
                n
            )));
        }
        Ok(Literal { ty: self.ty, dims: dims.to_vec(), bytes: self.bytes.clone(), tuple: None })
    }

    pub fn element_count(&self) -> usize {
        self.bytes.len() / self.ty.size_bytes()
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    fn check_ty<T: NativeType>(&self, what: &str) -> Result<()> {
        if self.ty != T::TY {
            return Err(Error::Host(format!(
                "{what}: literal holds {:?}, caller asked for {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        self.check_ty::<T>("to_vec")?;
        let w = T::TY.size_bytes();
        Ok(self.bytes.chunks_exact(w).map(T::from_le).collect())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.check_ty::<T>("get_first_element")?;
        let w = T::TY.size_bytes();
        if self.bytes.len() < w {
            return Err(Error::Host("get_first_element on empty literal".into()));
        }
        Ok(T::from_le(&self.bytes[..w]))
    }

    /// Copy the payload into a caller-provided slice (the zero-alloc
    /// fetch path, `engine::fill_vec_f32`).
    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        self.check_ty::<T>("copy_raw_to")?;
        if dst.len() != self.element_count() {
            return Err(Error::Host(format!(
                "copy_raw_to: dst holds {} elements, literal {}",
                dst.len(),
                self.element_count()
            )));
        }
        let w = T::TY.size_bytes();
        for (d, b) in dst.iter_mut().zip(self.bytes.chunks_exact(w)) {
            *d = T::from_le(b);
        }
        Ok(())
    }

    /// Decompose a tuple literal into its leaves.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.tuple {
            Some(leaves) => Ok(leaves),
            None => Err(Error::Host("to_tuple on a non-tuple literal".into())),
        }
    }
}

/// Parsed HLO module. The stub only records where it came from; parsing
/// happens inside the real bindings' C++ side.
#[derive(Debug)]
pub struct HloModuleProto {
    _path: std::path::PathBuf,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        // Reading the file keeps error behaviour honest (missing
        // artifacts fail here, exactly like the real parser would)...
        std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error::Host(format!("reading {}: {e}", path.as_ref().display())))?;
        // ...but actually parsing HLO needs the real bindings.
        Err(Error::Stub("HloModuleProto::from_text_file (HLO parsing)"))
    }
}

#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle. Never constructible from the stub (only a real
/// runtime hands these out), so the device-resident paths are
/// unreachable rather than silently wrong.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Stub("PjRtBuffer::to_literal_sync"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Stub("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _inputs: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Stub("PjRtLoadedExecutable::execute_b"))
    }
}

#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The entry point every engine-backed path goes through first:
    /// failing here routes callers onto their artifact-unavailable /
    /// mock-backed fallbacks before any other stub surface is touched.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Stub("PjRtClient::cpu (PJRT runtime)"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Stub("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::Stub("PjRtClient::buffer_from_host_literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, -2.5, 3.25]);
        assert_eq!(l.element_count(), 3);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.25]);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
    }

    #[test]
    fn literal_reshape_checks_counts() {
        let l = Literal::vec1(&[0i32; 6]);
        assert_eq!(l.reshape(&[2, 3]).unwrap().shape(), &[2, 3]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn literal_copy_raw_and_dtype_guard() {
        let l = Literal::vec1(&[7i8, -7]);
        let mut out = vec![0i8; 2];
        l.copy_raw_to(&mut out).unwrap();
        assert_eq!(out, vec![7, -7]);
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn device_surface_is_stubbed() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
        // the bounds anyhow's `?` conversion needs
        fn assert_anyhow_compatible<E: std::error::Error + Send + Sync + 'static>() {}
        assert_anyhow_compatible::<Error>();
    }
}
