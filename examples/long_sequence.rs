//! Long-sequence scaling — regenerates paper Fig 4: local+sparse hybrids
//! with constant k per head as T grows (sparsity rho = T/k rises), MoSA
//! vs fixed vs routing.
//!
//!     make artifacts-longseq && cargo run --release --example long_sequence
//!     [-- --steps 120 --lengths 256,512,1024]
//!
//! Head counts were frozen at the base length's IsoFLOP solution (like
//! the paper's 60-head setup solved at T=1024), so MoSA/fixed FLOPs per
//! token stay flat while routing's grow with T — Fig 4's cost asymmetry.

use anyhow::Result;
use mosa::config::RunConfig;
use mosa::experiments::report::{print_table, save_results};
use mosa::experiments::{build_datasets, run_variant_cached, VariantResult};
use mosa::runtime::{Engine, Manifest};
use mosa::util::cli::Args;

fn main() -> Result<()> {
    mosa::util::init_logging();
    let args = Args::parse(std::env::args().skip(1));
    let mut rc = RunConfig::from_args(&args);
    if !args.has("steps") {
        rc.steps = 120; // long-T steps are slow; Fig 4 needs the ranking, not convergence
    }
    if !args.has("corpus-bytes") {
        rc.corpus_bytes = 800_000; // long windows need a longer stream
    }
    let lengths: Vec<usize> = args
        .get_or("lengths", "256,512,1024,2048")
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();

    let manifest = Manifest::load(&rc.artifacts_dir)?;
    let mut engine = Engine::cpu()?;
    let (train_ds, test_ds) = build_datasets(&rc, 512)?;

    let mut rows: Vec<VariantResult> = Vec::new();
    for t in &lengths {
        for kind in ["mosa", "fixed", "routing"] {
            let name = format!("ls{t}_{kind}");
            let variant = match manifest.variant(&name) {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("skipping {name}: {e}");
                    continue;
                }
            };
            let res = run_variant_cached(&mut engine, &manifest, variant, &train_ds, &test_ds, &rc)?;
            println!(
                "  [{}] T={} rho={} ppl={:.3} flops/tok={:.1}M",
                name,
                t,
                res.rho,
                res.test_ppl,
                res.flops_fwd as f64 / *t as f64 / 1e6
            );
            rows.push(res);
        }
    }

    print_table("long-sequence scaling (Fig 4 series)", &rows);
    // Fig 4 claim check: MoSA lowest ppl per length.
    println!("\nper-length ranking:");
    for t in &lengths {
        let mut at: Vec<&VariantResult> = rows.iter().filter(|r| r.seq_len == *t).collect();
        if at.is_empty() {
            continue;
        }
        at.sort_by(|a, b| a.test_ppl.partial_cmp(&b.test_ppl).unwrap());
        let order: Vec<String> = at
            .iter()
            .map(|r| format!("{} {:.2}", r.sparse_kind, r.test_ppl))
            .collect();
        println!("  T={:<5} {}", t, order.join("  >  "));
    }
    save_results(format!("{}/long_sequence.json", rc.results_dir), "long_sequence", &rows)?;
    Ok(())
}
