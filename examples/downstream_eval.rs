//! Downstream zero-shot evaluation — regenerates paper Table 3's
//! structure on the synthetic task suite (recall / choice / agreement;
//! see evalharness docs for the mapping to LAMBADA / HellaSwag / BLiMP).
//!
//! Each core variant is trained on the shared corpus, then scored with
//! the short-sequence program that applies the paper's adaptive
//! k = max(T/rho, 2) rule (Sec 3.5).
//!
//!     make artifacts && cargo run --release --example downstream_eval
//!     [-- --steps 250 --n 60]

use anyhow::Result;
use mosa::config::RunConfig;
use mosa::data::{Bpe, CorpusGen};
use mosa::evalharness::{evaluate_tasks, make_tasks, TaskKind};
use mosa::experiments::{build_datasets, run_variant};
use mosa::runtime::{Engine, Manifest};
use mosa::util::cli::Args;
use mosa::util::json::Json;

fn main() -> Result<()> {
    mosa::util::init_logging();
    let args = Args::parse(std::env::args().skip(1));
    let mut rc = RunConfig::from_args(&args);
    if !args.has("steps") {
        rc.steps = 250;
    }
    let n = args.get_usize("n", 60);

    let manifest = Manifest::load(&rc.artifacts_dir)?;
    let mut engine = Engine::cpu()?;
    let (train_ds, test_ds) = build_datasets(&rc, 512)?;
    let text = CorpusGen::new(rc.seed + 1000).generate(rc.corpus_bytes);
    let bpe = Bpe::train(text.as_bytes(), 512)?;

    let names = ["micro_dense", "micro_mosa_r8", "micro_fixed_r8", "micro_routing_r8"];
    let mut table: Vec<(String, f64, Vec<(String, f64)>)> = Vec::new();
    for name in names {
        let variant = manifest.variant(name)?;
        let (res, _, state) =
            run_variant(&mut engine, &manifest, variant, &train_ds, &test_ds, &rc)?;
        let mut accs = Vec::new();
        for kind in TaskKind::all() {
            let tasks = make_tasks(kind, n, rc.seed + 7);
            let acc = evaluate_tasks(&mut engine, &manifest, variant, &state, &bpe, &tasks)?;
            accs.push((kind.name().to_string(), acc));
        }
        println!(
            "[{}] ppl {:.3} | {}",
            name,
            res.test_ppl,
            accs.iter().map(|(k, a)| format!("{k} {a:.2}")).collect::<Vec<_>>().join("  ")
        );
        table.push((name.to_string(), res.test_ppl, accs));
    }

    println!("\n== downstream zero-shot accuracy (Table 3 analogue, n={n}) ==");
    println!("{:<22} {:>8} {:>8} {:>8} {:>10}", "model", "recall", "choice", "agree", "test ppl");
    for (name, ppl, accs) in &table {
        println!(
            "{:<22} {:>8.2} {:>8.2} {:>8.2} {:>10.3}",
            name, accs[0].1, accs[1].1, accs[2].1, ppl
        );
    }
    println!("(expected shape per the paper: MoSA strong on recall/choice, weaker on");
    println!(" the short-sequence `agreement` suite — the BLiMP effect of Sec 3.5)");

    let j = Json::Arr(
        table
            .iter()
            .map(|(name, ppl, accs)| {
                Json::obj(vec![
                    ("model", Json::str(name.clone())),
                    ("ppl", Json::num(*ppl)),
                    (
                        "accs",
                        Json::Obj(accs.iter().map(|(k, a)| (k.clone(), Json::num(*a))).collect()),
                    ),
                ])
            })
            .collect(),
    );
    std::fs::create_dir_all(&rc.results_dir)?;
    std::fs::write(format!("{}/downstream.json", rc.results_dir), j.to_string_pretty())?;
    Ok(())
}
