//! Generate quickstart: the smallest end-to-end use of the serving path.
//!
//! Trains `micro_mosa_r8` briefly (so the sampled text is corpus-shaped,
//! not uniform noise), then serves a batch of prompts through the
//! device-resident decode path: prefill once, decode_step per token,
//! continuous batching over the fixed slots, greedy sampling.
//!
//!     make artifacts && cargo run --release --example generate

use anyhow::Result;
use mosa::coordinator::{Trainer, TrainOptions};
use mosa::data::TokenDataset;
use mosa::decode::{generate, GenerateOptions, SamplePolicy, SeqRequest};
use mosa::kvcache;
use mosa::runtime::{Engine, Manifest};

fn main() -> Result<()> {
    mosa::util::init_logging();

    // 1. artifacts: the decode programs ride in the core set
    let manifest = Manifest::load("artifacts")?;
    let variant = manifest.variant("micro_mosa_r8")?;
    let step = variant.program("decode_step")?;
    let capacity = step.capacity.unwrap_or(variant.config.seq_len);
    println!(
        "variant {}: KV cache {} bytes/seq at context {} (dense baseline would be {})",
        variant.name,
        kvcache::kv_bytes_total(&variant.config, capacity),
        capacity,
        // the paper's comparison point: same context, all-dense head count
        {
            let mut dense = variant.config.clone();
            dense.n_dense = variant.base_heads;
            dense.n_sparse = 0;
            dense.sparse_kind = "none".into();
            kvcache::kv_bytes_total(&dense, capacity)
        }
    );

    // 2. a short training run so the model has something to say
    let ds = TokenDataset::build(1000, 200_000, variant.config.vocab, None)?;
    let (train_ds, _) = ds.split(0.9);
    let mut engine = Engine::cpu()?;
    let trainer = Trainer::new(&manifest, variant);
    let mut sampler = train_ds.sampler(7);
    let (state, _) = trainer.train(&mut engine, &mut sampler, &TrainOptions::quick(60))?;

    // 3. serve: more requests than slots exercises continuous batching
    let n_seqs = step.batch.unwrap_or(variant.batch) + 2;
    let prompt: Vec<i32> = train_ds.ids[..12].to_vec();
    let requests: Vec<SeqRequest> = (0..n_seqs as u64)
        .map(|id| SeqRequest { id, prompt: prompt.clone(), max_new: 24 })
        .collect();
    let opts = GenerateOptions {
        max_new: 24,
        policy: SamplePolicy::TopK { k: 8, temperature: 0.9 },
        seed: 1,
        eos: None,
        use_prefill: true,
        device_resident: true,
        device_sample: true,
        use_paged: true,
    };
    let t0 = std::time::Instant::now();
    let finished = generate(&mut engine, &manifest, variant, state, requests, &opts)?;
    let total: usize = finished.iter().map(|f| f.generated.len()).sum();
    println!(
        "served {} sequences / {} tokens in {:.2}s ({:.1} tok/s)",
        finished.len(),
        total,
        t0.elapsed().as_secs_f64(),
        total as f64 / t0.elapsed().as_secs_f64().max(1e-9)
    );
    for f in finished.iter().take(3) {
        println!("[seq {}] generated token ids: {:?}", f.id, &f.generated);
    }
    Ok(())
}
