//! Resource usage comparison — regenerates paper Table 2's structure:
//! a dense baseline vs a MoSA hybrid, reporting wall-clock per step
//! (measured), modelled training-activation memory, and exact KV-cache
//! pairs. The paper matched perplexity by adding MoSA heads; at our scale
//! we use the FLOP-matched pair and report the ppl alongside (the *shape*
//! claim: MoSA simultaneously >= quality, <= time, <= memory, << KV).
//!
//!     make artifacts && cargo run --release --example resource_match
//!     [-- --steps 120]

use anyhow::Result;
use mosa::config::RunConfig;
use mosa::experiments::report::{format_si, save_results};
use mosa::experiments::{build_datasets, run_variant_cached, VariantResult};
use mosa::kvcache;
use mosa::runtime::{Engine, Manifest};
use mosa::util::cli::Args;

fn main() -> Result<()> {
    mosa::util::init_logging();
    let args = Args::parse(std::env::args().skip(1));
    let mut rc = RunConfig::from_args(&args);
    if !args.has("steps") {
        rc.steps = 120;
    }

    let manifest = Manifest::load(&rc.artifacts_dir)?;
    let mut engine = Engine::cpu()?;
    let (train_ds, test_ds) = build_datasets(&rc, 512)?;

    // micro_mosa_r8_match is the perplexity-matched configuration (paper
    // Table 2: fewer MoSA heads targeting the dense baseline's quality);
    // the *_r8 variants are the FLOP-matched ones from the sweep.
    let names = [
        "micro_dense",
        "micro_mosa_r8_match",
        "micro_mosa_r8",
        "micro_fixed_r8",
        "micro_routing_r8",
    ];
    let mut rows: Vec<VariantResult> = Vec::new();
    for name in names {
        let variant = match manifest.variant(name) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("skipping {name}: {e}");
                continue;
            }
        };
        let res = run_variant_cached(&mut engine, &manifest, variant, &train_ds, &test_ds, &rc)?;
        rows.push(res);
    }

    // Table 2 layout
    println!("\n== resource usage, FLOP-matched (Table 2 analogue) ==");
    println!(
        "{:<22} {:>10} {:>10} {:>12} {:>12} {:>12}",
        "", "ppl ↓", "ms/step ↓", "act-mem ↓", "KV pairs ↓", "KV bytes"
    );
    let dense = rows[0].clone();
    for r in &rows {
        let cfg = &manifest.variant(&r.name)?.config;
        println!(
            "{:<22} {:>10.3} {:>10.1} {:>12} {:>12} {:>12}",
            r.name,
            r.test_ppl,
            r.ms_per_step,
            format_si(r.act_bytes as f64),
            r.kv_pairs,
            format_si(kvcache::kv_bytes_total(cfg, cfg.seq_len) as f64),
        );
    }
    println!("\nGains of MoSA vs dense:");
    let m = &rows[1];
    println!(
        "  wall/step {:+.1}%   act-mem {:+.1}%   KV {:+.1}%   ppl {:+.1}%",
        (m.ms_per_step / dense.ms_per_step - 1.0) * 100.0,
        (m.act_bytes as f64 / dense.act_bytes as f64 - 1.0) * 100.0,
        (m.kv_pairs as f64 / dense.kv_pairs as f64 - 1.0) * 100.0,
        (m.test_ppl / dense.test_ppl - 1.0) * 100.0,
    );

    // Paper-scale KV columns of Table 2 (exact, analytic):
    println!("\n== paper-scale KV totals per layer (Table 2 KV column, exact) ==");
    for (label, nd, ns, k, t, paper) in [
        ("Tiny  dense", 9usize, 0usize, 0usize, 1024usize, "9.2K"),
        ("Tiny  MoSA ", 4, 17, 32, 1024, "4.5K"),
        ("Small MoSA ", 4, 14, 32, 1024, "4.4K"),
        ("Med.  MoSA ", 4, 12, 32, 1024, "4.4K"),
        ("Large dense", 16, 0, 0, 1024, "16.4K"),
        ("Large MoSA ", 4, 16, 64, 1024, "5.0K"),
    ] {
        let cfg = mosa::runtime::ModelCfg {
            vocab: 8000, d_model: 512, d_head: 64, d_ff: 2048, n_layers: 1,
            seq_len: t, n_dense: nd, window: 0, n_sparse: ns,
            sparse_kind: if ns > 0 { "mosa".into() } else { "none".into() }, k_sel: k,
        };
        println!(
            "  {}  computed {:>6.1}K   paper {}",
            label,
            kvcache::kv_pairs_per_layer(&cfg, t) as f64 / 1e3,
            paper
        );
    }

    save_results(format!("{}/resource_match.json", rc.results_dir), "resource_match", &rows)?;
    Ok(())
}
