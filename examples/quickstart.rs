//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Loads the core `micro_mosa_r8` artifact, builds the synthetic dataset,
//! trains for 40 steps through PJRT and reports train loss + test ppl.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use mosa::config::RunConfig;
use mosa::coordinator::{LrSchedule, TrainOptions, Trainer};
use mosa::data::{SequentialWindows, TokenDataset};
use mosa::runtime::{Engine, Manifest};

fn main() -> Result<()> {
    mosa::util::init_logging();
    let rc = RunConfig::default();

    // 1. artifact manifest (written by `make artifacts`)
    let manifest = Manifest::load(&rc.artifacts_dir)?;
    let variant = manifest.variant("micro_mosa_r8")?;
    println!(
        "variant {}: {} dense + {} {} heads (k={} of T={}), {} params",
        variant.name,
        variant.config.n_dense,
        variant.config.n_sparse,
        variant.config.sparse_kind,
        variant.config.k_sel,
        variant.config.seq_len,
        variant.n_params
    );

    // 2. data: synthetic corpus -> BPE -> token stream
    let ds = TokenDataset::build(1000, 200_000, variant.config.vocab, Some(&rc.cache_dir))?;
    let (train_ds, test_ds) = ds.split(0.9);

    // 3. train 40 steps on the PJRT CPU client
    let mut engine = Engine::cpu()?;
    let trainer = Trainer::new(&manifest, variant);
    let opts = TrainOptions {
        steps: 40,
        schedule: LrSchedule::paper_like(1e-3, 4, 40),
        seed: 0,
        log_every: 10,
        use_chunk: false,
        checkpoint: None,
        eval_every: 0,
        prefetch: true, // batches + literals staged on a background thread
        device_resident: true, // train state stays on device between steps
    };
    let mut sampler = train_ds.sampler(7);
    let (state, metrics) = trainer.train(&mut engine, &mut sampler, &opts)?;

    // 4. held-out perplexity
    let mut eval = SequentialWindows::new(&test_ds);
    let ppl = trainer.evaluate(&mut engine, &mut eval, &state, 4)?;
    println!(
        "\nquickstart done: loss {:.3} -> {:.3}, test ppl {:.2}",
        metrics.records.first().map(|r| r.loss).unwrap_or(f64::NAN),
        metrics.tail_loss(5),
        ppl
    );
    Ok(())
}
