//! End-to-end driver (DESIGN.md §validation): the full three-layer stack
//! on a real (synthetic-corpus) workload.
//!
//! Pipeline: corpus generation -> BPE training -> token stream -> Rust
//! coordinator trains a hybrid-MoSA transformer AND the FLOP-matched
//! dense baseline for several hundred steps through PJRT -> loss curves
//! to results/*.csv -> held-out perplexity + downstream zero-shot probes.
//!
//!     make artifacts && cargo run --release --example train_lm -- --steps 300
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use anyhow::Result;
use mosa::config::RunConfig;
use mosa::data::{Bpe, CorpusGen};
use mosa::evalharness::{evaluate_tasks, make_tasks, TaskKind};
use mosa::experiments::{build_datasets, run_variant};
use mosa::runtime::{Engine, Manifest};
use mosa::util::cli::Args;

fn main() -> Result<()> {
    mosa::util::init_logging();
    let args = Args::parse(std::env::args().skip(1));
    let mut rc = RunConfig::from_args(&args);
    if !args.has("steps") {
        rc.steps = 300;
    }

    let manifest = Manifest::load(&rc.artifacts_dir)?;
    let mut engine = Engine::cpu()?;

    let pair = ["micro_dense", "micro_mosa_r8"];
    let (train_ds, test_ds) = build_datasets(&rc, 512)?;
    println!(
        "corpus: {} train / {} test tokens (BPE vocab 512)",
        train_ds.ids.len(),
        test_ds.ids.len()
    );

    let mut rows = Vec::new();
    let mut states = Vec::new();
    for name in pair {
        let variant = manifest.variant(name)?;
        let (res, metrics, state) = run_variant(&mut engine, &manifest, variant, &train_ds, &test_ds, &rc)?;
        let csv = metrics.save_csv(&rc.results_dir)?;
        println!(
            "[{}] tail-loss {:.4}  test-ppl {:.3}  {:.0} ms/step  (curve {})",
            name,
            res.train_tail_loss,
            res.test_ppl,
            res.ms_per_step,
            csv.display()
        );
        rows.push(res);
        states.push((name, state));
    }

    // downstream probes on both models (Table 3 analogue, small n)
    let text = CorpusGen::new(rc.seed + 1000).generate(rc.corpus_bytes);
    let bpe = Bpe::train(text.as_bytes(), 512)?;
    for (name, state) in &states {
        let variant = manifest.variant(name)?;
        if !variant.programs.contains_key("score_short") {
            continue;
        }
        print!("[{}] downstream:", name);
        for kind in TaskKind::all() {
            let tasks = make_tasks(kind, 30, rc.seed + 7);
            let acc = evaluate_tasks(&mut engine, &manifest, variant, state, &bpe, &tasks)?;
            print!("  {} {:.2}", kind.name(), acc);
        }
        println!();
    }

    mosa::experiments::report::print_table("end-to-end: dense vs MoSA hybrid", &rows);
    mosa::experiments::report::save_results(
        format!("{}/train_lm.json", rc.results_dir),
        "train_lm",
        &rows,
    )?;
    let d = &rows[0];
    let m = &rows[1];
    println!(
        "\nIsoFLOP result: MoSA ppl {:.2} vs dense ppl {:.2} ({:+.1}%)  |  KV pairs {} vs {} ({:+.1}%)",
        m.test_ppl,
        d.test_ppl,
        (m.test_ppl / d.test_ppl - 1.0) * 100.0,
        m.kv_pairs,
        d.kv_pairs,
        (m.kv_pairs as f64 / d.kv_pairs as f64 - 1.0) * 100.0,
    );
    Ok(())
}
