//! IsoFLOP sweep — regenerates the data behind paper Table 1, Fig 3
//! (hybrid curves), Fig 5 (pure MoSA), Fig 6 (loss curves) and Fig 7
//! (dense-head ablation) at the trainable micro/mini budgets.
//!
//!     make artifacts-all && cargo run --release --example isoflop_sweep
//!     [-- --steps 200 --groups sweep,pure,ablate --budget micro]
//!
//! Every variant trains on the same corpus with the same schedule; head
//! counts were fixed by the IsoFLOP solver at artifact-build time, so the
//! comparison is FLOP-matched by construction. Loss curves land in
//! results/<variant>.csv (Fig 6); the summary table + results/isoflop.json
//! hold the ppl-vs-sparsity series (Table 1 / Fig 3 / Fig 5 / Fig 7).

use anyhow::Result;
use mosa::config::RunConfig;
use mosa::experiments::report::{print_table, save_results};
use mosa::experiments::{build_datasets, run_variant_cached, VariantResult};
use mosa::runtime::{Engine, Manifest};
use mosa::util::cli::Args;

fn main() -> Result<()> {
    mosa::util::init_logging();
    let args = Args::parse(std::env::args().skip(1));
    let rc = RunConfig::from_args(&args);
    let groups: Vec<String> = args
        .get_or("groups", "core,sweep,pure,ablate")
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let budget = args.get_or("budget", ""); // "" = all; or "micro"/"mini"

    let manifest = Manifest::load(&rc.artifacts_dir)?;
    let mut engine = Engine::cpu()?;
    let (train_ds, test_ds) = build_datasets(&rc, 512)?;

    let mut names: Vec<String> = manifest
        .variants
        .values()
        .filter(|v| groups.iter().any(|g| &v.group == g))
        .filter(|v| budget.is_empty() || v.name.starts_with(&budget))
        .map(|v| v.name.clone())
        .collect();
    names.sort();
    println!("sweeping {} variants: {:?}", names.len(), names);

    let mut rows: Vec<VariantResult> = Vec::new();
    for name in &names {
        let variant = manifest.variant(name)?;
        let res = run_variant_cached(&mut engine, &manifest, variant, &train_ds, &test_ds, &rc)?;
        println!(
            "  [{}] rho={} heads={}+{} ppl={:.3}",
            name, res.rho, res.n_dense, res.n_sparse, res.test_ppl
        );
        rows.push(res);
    }

    // Table 1 analogue: best sparse ppl per kind vs dense, with relative %.
    print_table("IsoFLOP sweep (Fig 3/5/7 series)", &rows);
    for budget_prefix in ["micro", "mini"] {
        let dense = rows
            .iter()
            .find(|r| r.name == format!("{budget_prefix}_dense"))
            .map(|r| r.test_ppl);
        if let Some(dense_ppl) = dense {
            println!("\nTable-1 analogue — budget {budget_prefix} (dense ppl {dense_ppl:.3}):");
            for kind in ["mosa", "fixed", "routing"] {
                let best = rows
                    .iter()
                    .filter(|r| {
                        r.name.starts_with(budget_prefix)
                            && r.sparse_kind == kind
                            && r.group != "pure"
                            && r.group != "ablate"
                            && r.rho > 1
                    })
                    .min_by(|a, b| a.test_ppl.partial_cmp(&b.test_ppl).unwrap());
                if let Some(b) = best {
                    println!(
                        "  {:<8} best ppl {:.3} at rho={} ({:+.1}% vs dense)",
                        kind,
                        b.test_ppl,
                        b.rho,
                        (b.test_ppl / dense_ppl - 1.0) * 100.0
                    );
                }
            }
        }
    }

    save_results(format!("{}/isoflop.json", rc.results_dir), "isoflop_sweep", &rows)?;
    println!("\nwrote {}/isoflop.json", rc.results_dir);
    Ok(())
}
