#!/usr/bin/env bash
# Per-PR verify path: build, tests, lint (fmt + clippy -D warnings), and a
# smoke run of the host-side perf harness (tiny sizes; emits
# /tmp/BENCH_pipeline.smoke.json so perf regressions surface in review).
#
# Degrades gracefully when the Rust toolchain is not installed (some CI
# containers carry only the artifact toolchain): prints SKIP and exits 0,
# matching the tier-1 driver which runs cargo itself where available.
set -u
cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify: SKIP — cargo not on PATH in this container"
    exit 0
fi

# The repo ships no Cargo.toml: the manifest (and the baked xla crate)
# live in the external build harness. With a toolchain but no manifest,
# cargo can only fail on mechanics — skip honestly instead.
dir=.
if [ -f rust/Cargo.toml ]; then
    dir=rust
elif [ ! -f Cargo.toml ]; then
    echo "verify: SKIP — cargo is present but no Cargo.toml exists in the repo"
    echo "        (run from the build harness that supplies the manifest + xla crate)"
    exit 0
fi
cd "$dir" || exit 1

fail=0
run() {
    echo "+ $*"
    "$@" || { echo "verify: FAILED: $*"; fail=1; }
}

run cargo build --release
run cargo test -q
run cargo fmt --check
run cargo clippy --all-targets -- -D warnings
# perf smoke: host pipeline probes always run; the decode probe is
# artifact-gated (graceful `available: false` without `make artifacts`),
# so decode-latency regressions diff in BENCH_decode.smoke.json when
# artifacts are present and CI stays green when they are not.
# stale-result guard: a leftover smoke JSON from an earlier run must
# never be published as this PR's numbers
rm -f /tmp/BENCH_pipeline.smoke.json /tmp/BENCH_decode.smoke.json
run cargo run --release --bin mosa -- perf --smoke \
    --out /tmp/BENCH_pipeline.smoke.json \
    --decode-out /tmp/BENCH_decode.smoke.json

# keep the smoke reports in-repo so the perf trajectory accumulates as
# reviewable BENCH_*.json diffs per PR — only when this run produced them,
# and never clobber real measured decode numbers with an artifact-less
# `available: false` stub
root=$(pwd)
case "$dir" in rust) root=$(dirname "$root");; esac
if [ -f /tmp/BENCH_pipeline.smoke.json ]; then
    run cp /tmp/BENCH_pipeline.smoke.json "$root/BENCH_pipeline.json"
else
    echo "verify: perf smoke produced no pipeline report; BENCH_pipeline.json left untouched"
fi
if [ -f /tmp/BENCH_decode.smoke.json ] \
    && grep -q '"available": true' /tmp/BENCH_decode.smoke.json; then
    run cp /tmp/BENCH_decode.smoke.json "$root/BENCH_decode.json"
else
    echo "verify: decode smoke unavailable (no artifacts?); BENCH_decode.json left untouched"
fi

# zero-copy gate: with artifacts present, the device-sampling decode path
# must keep device->host traffic at O(batch) bytes per token (the ids
# download; fetching full logits would trip this at batch*vocab*4)
if ! [ -f /tmp/BENCH_decode.smoke.json ]; then
    echo "zero-copy gate: SKIP - no decode smoke report (perf run failed above)"
elif command -v python3 >/dev/null 2>&1; then
    run python3 - <<'PYEOF'
import json, sys
r = json.load(open("/tmp/BENCH_decode.smoke.json"))
if not r.get("available"):
    print("zero-copy gate: skipped (decode bench unavailable: no artifacts)")
    sys.exit(0)
checked, bad = 0, []
for v in r.get("variants", []):
    b = v.get("batch", 1)
    for arm in v.get("zero_copy", []):
        if arm.get("sample") == "device" and arm.get("donate_requested"):
            checked += 1
            hb = arm.get("host_bytes_per_token")
            if hb is None or hb > 16 * b:
                bad.append((v.get("variant"), hb, 16 * b))
if bad:
    print(f"zero-copy gate: FAILED {bad} (host_bytes_per_token > 16 x batch)")
    sys.exit(1)
print(f"zero-copy gate: OK ({checked} device-sampling arms within 16 x batch)")
PYEOF
else
    echo "zero-copy gate: SKIP - python3 not on PATH"
fi

if [ "$fail" -eq 0 ]; then
    echo "verify: OK"
fi
exit "$fail"
