#!/usr/bin/env bash
# Per-PR verify path: build, tests, lint (fmt + clippy -D warnings), and a
# smoke run of the host-side perf harness (tiny sizes; emits
# /tmp/BENCH_pipeline.smoke.json so perf regressions surface in review).
#
# Publication contract (the perf trajectory must never be silently empty):
# BENCH_pipeline.json and BENCH_decode.json exist at the repo root after
# every verify run. Real measured numbers are published whenever the perf
# smoke produced them; when a stage cannot run (no cargo, no artifacts),
# the guard says exactly WHY and publishes an `available: false` stub
# carrying the reason + a Python lowering smoke — so regressions can be
# argued from BENCH diffs per ROADMAP, and a missing toolchain is an
# explained data point instead of an empty trajectory. Stubs never
# overwrite reports holding real measured numbers.
set -u
cd "$(dirname "$0")"
root=$(pwd)

# ---------------------------------------------------------------------------
# fallback publisher: explain the skip AND still publish BENCH stubs
# ---------------------------------------------------------------------------
publish_fallback() {
    reason=$1
    echo "verify: SKIP — $reason"
    if ! command -v python3 >/dev/null 2>&1; then
        echo "verify: python3 also unavailable; BENCH files left as-is (nothing can publish)"
        exit 0
    fi
    if ! python3 -c "import jax" >/dev/null 2>&1; then
        echo "verify: python3 lacks jax; BENCH files left as-is (nothing can publish)"
        exit 0
    fi
    (cd python && python3 -m compile.verify_smoke \
        --pipeline-out "$root/BENCH_pipeline.json" \
        --decode-out "$root/BENCH_decode.json" \
        --reason "$reason")
    exit $?
}

if ! command -v cargo >/dev/null 2>&1; then
    publish_fallback "cargo not on PATH in this container"
fi

# rust/Cargo.toml exists (PR 8) with a vendored `xla` stub pinning
# resolution; a build harness that supplies the real xla crate may
# override it via a [patch] or its own manifest at the repo root.
dir=.
if [ -f rust/Cargo.toml ]; then
    dir=rust
elif [ ! -f Cargo.toml ]; then
    publish_fallback "cargo is present but no Cargo.toml exists in the repo (run from the build harness that supplies the manifest + xla crate)"
fi
cd "$dir" || exit 1

fail=0
run() {
    echo "+ $*"
    "$@" || { echo "verify: FAILED: $*"; fail=1; }
}

run cargo build --release
run cargo test -q
run cargo fmt --check
run cargo clippy --all-targets -- -D warnings
# perf smoke: host pipeline probes always run; the decode probe is
# artifact-gated (graceful `available: false` without `make artifacts`),
# so decode-latency regressions diff in BENCH_decode.smoke.json when
# artifacts are present and CI stays green when they are not.
# stale-result guard: a leftover smoke JSON from an earlier run must
# never be published as this PR's numbers
rm -f /tmp/BENCH_pipeline.smoke.json /tmp/BENCH_decode.smoke.json
run cargo run --release --bin mosa -- perf --smoke \
    --out /tmp/BENCH_pipeline.smoke.json \
    --decode-out /tmp/BENCH_decode.smoke.json
# chaos smoke: seeded fault plan against the serving loop (mock-backed,
# so it needs no artifacts). `mosa chaos` exits nonzero on any leaked
# page, invariant violation, or survivor-stream divergence.
run cargo run --release --bin mosa -- chaos --seed 17 \
    --plan 'fail@2;fail@5;slow@7:900;hold@3:4x120' \
    --out /tmp/chaos.smoke.json
# transport smokes (mock-backed, ephemeral loopback ports): the storm
# drives concurrent SSE streams under injected connection drops/stalls
# + deliberate mid-stream hangups and exits nonzero on any leaked page
# (a leaked connection IS a leaked page), non-prefix severed stream, or
# stuck drain; the loadgen exercises the overload + drain-under-load
# path and exits nonzero unless every request is accounted for leak-free.
run cargo run --release --bin mosa -- chaos --transport --seed 17 \
    --plan 'drop@5;drop@19;stall@9:25' \
    --out /tmp/chaos_transport.smoke.json
run cargo run --release --bin mosa -- loadgen --seed 17 --requests 24 \
    --rate-rps 400 --drain-after-frac 0.75 \
    --out /tmp/loadgen.smoke.json
# saturation smoke: open-loop arrivals at 4x capacity with overload
# control (token-bucket admission, brownout ladder, breaker) engaged and
# seeded wire faults riding along. Exits nonzero unless the overload
# contract holds: zero leaked pages, a well-formed drain-derived
# Retry-After on every 429/503, goodput above the floor while shedding,
# and every accepted stream a bit-identical prefix of its unloaded
# baseline.
run cargo run --release --bin mosa -- chaos --saturate --seed 17 \
    --rate-multiple 4 \
    --out /tmp/chaos_saturate.smoke.json

# ---------------------------------------------------------------------------
# publication: keep the smoke reports in-repo so the perf trajectory
# accumulates as reviewable BENCH_*.json diffs per PR. Reports are
# published unconditionally when the smoke produced them — including
# artifact-less `available: false` runs, which carry their reason — with
# one exception: an unavailable stub never clobbers a root report that
# holds real measured numbers (explanations lose to data).
# ---------------------------------------------------------------------------
publish_smoke() {
    src=$1; dst=$2
    if ! [ -f "$src" ]; then
        echo "verify: $dst NOT published — perf smoke produced no report at $src (run failed above?)"
        return
    fi
    if grep -q '"available": *false' "$src" \
        && [ -f "$dst" ] && grep -q '"available": *true' "$dst"; then
        echo "verify: $dst kept — new smoke is 'available: false' ($(grep -o '"reason": *"[^"]*"' "$src" | head -1)); existing report holds real measured numbers"
        return
    fi
    run cp "$src" "$dst"
}
publish_smoke /tmp/BENCH_pipeline.smoke.json "$root/BENCH_pipeline.json"
publish_smoke /tmp/BENCH_decode.smoke.json "$root/BENCH_decode.json"

# zero-copy + paged gates over the decode smoke (only meaningful when the
# decode bench had artifacts to measure)
if ! [ -f /tmp/BENCH_decode.smoke.json ]; then
    echo "decode gates: SKIP - no decode smoke report (perf run failed above)"
elif command -v python3 >/dev/null 2>&1; then
    run python3 - <<'PYEOF'
import json, sys
r = json.load(open("/tmp/BENCH_decode.smoke.json"))
# faults gate: the chaos counters are mock-backed, so they are real
# whenever the rust bench ran at all (artifacts or not) — gate them
# before the artifact-gated early exit below
faults = r.get("faults")
if faults and faults.get("available") is not False:
    fbad = []
    if faults.get("leaked_pages", 1) != 0:
        fbad.append(f"leaked_pages={faults.get('leaked_pages')}")
    if faults.get("invariant_violations", 1) != 0:
        fbad.append(f"invariant_violations={faults.get('invariant_violations')}")
    if faults.get("stream_mismatches", 1) != 0:
        fbad.append(f"stream_mismatches={faults.get('stream_mismatches')}")
    if not faults.get("recovered", 0) > 0:
        fbad.append(f"recovered={faults.get('recovered')} (fault recovery never exercised)")
    if fbad:
        print(f"faults gate: FAILED {fbad}")
        sys.exit(1)
    print(
        f"faults gate: OK (recovered={faults.get('recovered'):.0f}, "
        f"p99={faults.get('recovery_ms_p99', 0):.0f}ms logical, 0 pages leaked)"
    )
elif faults:
    print(f"faults gate: skipped (stub: {faults.get('reason', 'rust bench did not run')})")
else:
    print("faults gate: no faults key in the report (pre-serve bench?)")
# transport gate: loadgen latency arm over real loopback sockets —
# mock-backed like faults, so it too is real whenever the rust bench
# ran. Wall-clock percentiles are informational; the behavioural keys
# are the gate.
tr = r.get("transport")
if tr and tr.get("available") is not False:
    tbad = []
    if tr.get("leaked_pages", 1) != 0:
        tbad.append(f"leaked_pages={tr.get('leaked_pages')}")
    if tr.get("conserved") is not True:
        tbad.append(f"conserved={tr.get('conserved')}")
    if tr.get("errored", 1) != 0:
        tbad.append(f"errored={tr.get('errored')}")
    if not tr.get("completed", 0) > 0:
        tbad.append(f"completed={tr.get('completed')} (nothing streamed end-to-end)")
    if tr.get("ok") is not True:
        tbad.append("ok=false (unaccounted requests or dirty drain)")
    if tbad:
        print(f"transport gate: FAILED {tbad}")
        sys.exit(1)
    ttft = tr.get("ttft", {})
    itl = tr.get("itl", {})
    print(
        f"transport gate: OK ({tr.get('completed'):.0f} completed over loopback, "
        f"ttft p99 {ttft.get('p99_ms', 0):.1f}ms, itl p99 {itl.get('p99_ms', 0):.1f}ms, "
        f"drain {tr.get('drain_wall_ms', 0):.0f}ms, 0 pages leaked)"
    )
elif tr:
    print(f"transport gate: skipped (stub: {tr.get('reason', 'rust bench did not run')})")
else:
    print("transport gate: no transport key in the report (pre-transport bench?)")
# overload gate: the saturation arm at 1x/2x/4x. The 4x ("saturated")
# point carries the contract: zero leaks, every rejection a well-formed
# 429/503 with a measured Retry-After, accepted streams bit-identical
# prefixes of the unloaded baseline, goodput above the floor while
# shedding. Mock-backed like faults/transport.
ov = r.get("overload")
if ov and ov.get("available") is not False:
    obad = []
    sat = ov.get("saturated")
    if not isinstance(sat, dict):
        obad.append("no saturated (4x) point in the overload arm")
        sat = {}
    if sat.get("leaked_pages", 1) != 0:
        obad.append(f"leaked_pages={sat.get('leaked_pages')}")
    if sat.get("malformed_rejections", 1) != 0:
        obad.append(f"malformed_rejections={sat.get('malformed_rejections')}")
    if sat.get("mismatched_streams", 1) != 0:
        obad.append(f"mismatched_streams={sat.get('mismatched_streams')}")
    if not sat.get("rejected", 0) > 0:
        obad.append(f"rejected={sat.get('rejected')} (4x overload never shed)")
    if sat.get("goodput_tps", -1) < sat.get("goodput_floor_tps", 0):
        obad.append(
            f"goodput={sat.get('goodput_tps')}tps below floor {sat.get('goodput_floor_tps')}tps"
        )
    if ov.get("ok") is not True:
        obad.append("ok=false (overload contract violated)")
    if obad:
        print(f"overload gate: FAILED {obad}")
        sys.exit(1)
    print(
        f"overload gate: OK at 4x ({sat.get('completed'):.0f} completed, "
        f"{sat.get('rejected'):.0f} shed with Retry-After mean "
        f"{sat.get('retry_after_mean_s', 0):.1f}s, goodput "
        f"{sat.get('goodput_tps', 0):.1f}tps >= {sat.get('goodput_floor_tps', 0):.1f}tps floor, "
        f"0 pages leaked)"
    )
elif ov:
    print(f"overload gate: skipped (stub: {ov.get('reason', 'rust bench did not run')})")
else:
    print("overload gate: no overload key in the report (pre-overload bench?)")
# prefix-sharing gate: the shared-prompt smoke. The arm fans 1x/8x/32x
# requests off one common prompt with sharing on vs a --no-prefix-share
# twin: streams must be bit-identical (sharing is an allocation
# optimization, never a compute change), nothing may leak (pool fully
# free, zero shared/pinned refs at teardown), and at 32x the shared run
# must allocate <= 0.5x the pages per request of the unshared twin.
# Mock-backed like faults/transport/overload; this doubles as the
# shared-prompt serving smoke (the loadgen CLI draws random prompts).
ps = r.get("prefix_sharing")
if ps and ps.get("available") is not False:
    pbad = []
    if ps.get("leaked_pages", 1) != 0:
        pbad.append(f"leaked_pages={ps.get('leaked_pages')}")
    if ps.get("stream_mismatches", 1) != 0:
        pbad.append(f"stream_mismatches={ps.get('stream_mismatches')} (shared != unshared twin)")
    ratio = ps.get("alloc_ratio_32x")
    if ratio is None or ratio > 0.5:
        pbad.append(f"alloc_ratio_32x={ratio} (> 0.5x unshared)")
    if ps.get("ok") is not True:
        pbad.append("ok=false (prefix-sharing contract violated)")
    if pbad:
        print(f"prefix-sharing gate: FAILED {pbad}")
        sys.exit(1)
    print(
        f"prefix-sharing gate: OK (32x fan-out allocs/request at {ratio:.2f}x unshared "
        f"<= 0.5x, streams bit-identical, 0 pages leaked)"
    )
elif ps:
    print(f"prefix-sharing gate: skipped (stub: {ps.get('reason', 'rust bench did not run')})")
else:
    print("prefix-sharing gate: no prefix_sharing key in the report (pre-sharing bench?)")
if not r.get("available"):
    print(f"decode gates: skipped (decode bench unavailable: {r.get('reason', 'no artifacts')})")
    sys.exit(0)
checked, bad = 0, []
for v in r.get("variants", []):
    b = v.get("batch", 1)
    for arm in v.get("zero_copy", []):
        if arm.get("sample") == "device" and arm.get("donate_requested"):
            checked += 1
            hb = arm.get("host_bytes_per_token")
            if hb is None or hb > 16 * b:
                bad.append((v.get("variant"), hb, 16 * b))
if bad:
    print(f"zero-copy gate: FAILED {bad} (host_bytes_per_token > 16 x batch)")
    sys.exit(1)
print(f"zero-copy gate: OK ({checked} device-sampling arms within 16 x batch)")
# paged gate: the overcommitted pools must keep resident cache bytes at
# <= 0.5x the contiguous layout (the ISSUE acceptance ratio)
pchecked, pbad = 0, []
for v in r.get("variants", []):
    paged = v.get("paged")
    if not paged:
        continue
    pchecked += 1
    ratio = paged.get("resident_ratio_paged_vs_contiguous")
    if ratio is None or ratio > 0.5:
        pbad.append((v.get("variant"), ratio))
if pbad:
    print(f"paged gate: FAILED {pbad} (resident paged/contiguous > 0.5)")
    sys.exit(1)
if pchecked:
    print(f"paged gate: OK ({pchecked} variants with resident ratio <= 0.5)")
else:
    print("paged gate: no paged arms in the report (pre-paging artifacts?)")
# quantized gate: the i8 pools must keep resident payload bytes at
# <= 0.30x the contiguous f32 layout (overcommit x the 4x dtype factor)
# AND the teacher-forced greedy stream must match the f32 paged twin
# exactly — per-page absmax scaling may perturb logits but never the
# argmax at micro scale
qchecked, qbad = 0, []
for v in r.get("variants", []):
    q = v.get("quantized")
    if not q:
        continue
    qchecked += 1
    ratio = q.get("resident_ratio_quantized_vs_contiguous")
    if ratio is None or ratio > 0.30:
        qbad.append((v.get("variant"), "resident_ratio", ratio))
    mism = q.get("greedy_stream_mismatches")
    if mism is None or mism != 0:
        qbad.append((v.get("variant"), "greedy_stream_mismatches", mism))
if qbad:
    print(f"quantized gate: FAILED {qbad}")
    sys.exit(1)
if qchecked:
    devs = [v["quantized"].get("max_abs_logit_deviation", 0.0)
            for v in r.get("variants", []) if v.get("quantized")]
    print(
        f"quantized gate: OK ({qchecked} variants: resident <= 0.30x contiguous f32, "
        f"0 greedy mismatches, max |dlogit| {max(devs):.2e})"
    )
else:
    print("quantized gate: no quantized arms in the report (pre-quantization artifacts?)")
PYEOF
else
    echo "decode gates: SKIP - python3 not on PATH"
fi

if [ "$fail" -eq 0 ]; then
    echo "verify: OK"
fi
exit "$fail"
