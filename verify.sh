#!/usr/bin/env bash
# Per-PR verify path: build, tests, lint (fmt + clippy -D warnings), and a
# smoke run of the host-side perf harness (tiny sizes; emits
# /tmp/BENCH_pipeline.smoke.json so perf regressions surface in review).
#
# Degrades gracefully when the Rust toolchain is not installed (some CI
# containers carry only the artifact toolchain): prints SKIP and exits 0,
# matching the tier-1 driver which runs cargo itself where available.
set -u
cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify: SKIP — cargo not on PATH in this container"
    exit 0
fi

# The repo ships no Cargo.toml: the manifest (and the baked xla crate)
# live in the external build harness. With a toolchain but no manifest,
# cargo can only fail on mechanics — skip honestly instead.
dir=.
if [ -f rust/Cargo.toml ]; then
    dir=rust
elif [ ! -f Cargo.toml ]; then
    echo "verify: SKIP — cargo is present but no Cargo.toml exists in the repo"
    echo "        (run from the build harness that supplies the manifest + xla crate)"
    exit 0
fi
cd "$dir" || exit 1

fail=0
run() {
    echo "+ $*"
    "$@" || { echo "verify: FAILED: $*"; fail=1; }
}

run cargo build --release
run cargo test -q
run cargo fmt --check
run cargo clippy --all-targets -- -D warnings
# perf smoke: host pipeline probes always run; the decode probe is
# artifact-gated (graceful `available: false` without `make artifacts`),
# so decode-latency regressions diff in BENCH_decode.smoke.json when
# artifacts are present and CI stays green when they are not.
run cargo run --release --bin mosa -- perf --smoke \
    --out /tmp/BENCH_pipeline.smoke.json \
    --decode-out /tmp/BENCH_decode.smoke.json

if [ "$fail" -eq 0 ]; then
    echo "verify: OK"
fi
exit "$fail"
