"""Decode-path tests: teacher-forcing equivalence of prefill + decode_step
against the training-time score computation, cache layout accounting, and
the streaming expert-choice properties of the MoSA cache.

Exactness contract (see compile/decode.py module doc):
- prefill ≡ score for EVERY head kind (same head functions, bit-for-bit);
- prefill + T×decode_step ≡ score for dense, local and fixed heads (fully
  causal) and for MoSA whenever its selection is causal over the compared
  window (expert-choice is non-causal in general; with k_sel = T the
  selection is total and the decode path must match exactly);
- for MoSA with k_sel < T, the streaming eviction cache must equal
  expert-choice top-k over the generated *prefix* — checked end-to-end at
  layer 0, where router inputs are history-independent.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import decode as dec
from compile.model import ModelConfig, forward, init_params, token_logprobs

jax.config.update("jax_platform_name", "cpu")

B = 2


def make_cfg(**kw):
    base = dict(
        vocab=48, d_model=16, d_head=8, d_ff=32, n_layers=2, seq_len=16,
        n_dense=2, window=0, n_sparse=0, sparse_kind="none", k_sel=0,
        use_kernel=False,
    )
    base.update(kw)
    return ModelConfig(**base)


CFGS = {
    "dense": make_cfg(),
    "local": make_cfg(window=4),
    "mosa": make_cfg(n_dense=1, n_sparse=2, sparse_kind="mosa", k_sel=4),
    "mosa_full": make_cfg(n_dense=1, n_sparse=2, sparse_kind="mosa", k_sel=16),
    "fixed": make_cfg(n_dense=1, n_sparse=2, sparse_kind="fixed", k_sel=4),
    "routing": make_cfg(n_dense=1, n_sparse=2, sparse_kind="routing", k_sel=4),
}


def setup(cfg, seed=0):
    params, state = init_params(jax.random.PRNGKey(seed), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(seed + 1), (B, cfg.seq_len), 0, cfg.vocab)
    return params, state, tokens.astype(jnp.int32)


def run_decode(cfg, params, state, tokens, p0, cap=32):
    """prefill(plen=p0) then teacher-forced decode_step over the rest."""
    prefill = dec.make_prefill(cfg, cap, B)
    plen = jnp.full((B,), p0, jnp.int32)
    lps, last, caches = prefill(params, state, tokens, plen)
    step = dec.make_decode_step(cfg, cap, B)
    zero = jnp.zeros((B,), jnp.int32)
    outs = []
    for t in range(p0, cfg.seq_len):
        pos = jnp.full((B,), t, jnp.int32)
        logits, caches = step(params, state, tokens[:, t], pos, zero, caches)
        outs.append(logits)
    return lps, last, outs, caches


# ---------------------------------------------------------------------------
# cache layout
# ---------------------------------------------------------------------------


def test_cache_layout_payload_bytes_match_accounting():
    """kv-kind leaf bytes per sequence == the closed-form KV accounting
    (mirrors rust kvcache::kv_bytes_total at t = capacity)."""
    cap = 64
    for name, cfg in CFGS.items():
        struct = dec.cache_struct(cfg, B, cap)
        flat, _ = jax.tree_util.tree_flatten_with_path(struct)
        payload = 0
        for path, leaf in flat:
            leafname = str(path[-1]).strip("[']")
            meta = dec.leaf_meta(leafname)
            assert meta["kind"] in ("kv", "meta")
            if meta["kind"] == "kv":
                payload += int(np.prod(leaf.shape)) * 4
        dense_pairs = (min(cfg.window, cap) if cfg.window > 0 else cap) * cfg.n_dense
        sparse_pairs = {
            "mosa": cfg.k_sel * cfg.n_sparse,
            "fixed": cfg.k_sel * cfg.n_sparse,
            "routing": cap * cfg.n_sparse,
            "none": 0,
        }[cfg.sparse_kind]
        expect = cfg.n_layers * (dense_pairs + sparse_pairs) * 2 * cfg.d_head * 4
        assert payload // B == expect, name


# ---------------------------------------------------------------------------
# prefill ≡ score (every head kind)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(CFGS))
def test_prefill_matches_score(name):
    cfg = CFGS[name]
    params, state, tokens = setup(cfg)
    prefill = dec.make_prefill(cfg, 32, B)
    plen = jnp.full((B,), cfg.seq_len, jnp.int32)
    lps, last, _ = prefill(params, state, tokens, plen)
    # score program semantics: forward the same seq_len window
    ext = jnp.concatenate([tokens, jnp.zeros((B, 1), jnp.int32)], axis=1)
    ref = token_logprobs(params, state, ext, cfg)  # [B, T]
    np.testing.assert_allclose(np.asarray(lps), np.asarray(ref[:, : cfg.seq_len - 1]),
                               atol=1e-5, rtol=1e-5)
    ref_logits, _ = forward(params, state, tokens, cfg)
    np.testing.assert_allclose(np.asarray(last), np.asarray(ref_logits[:, -1]),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# teacher forcing: prefill + decode_step ≡ score
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["dense", "local", "fixed", "mosa_full"])
def test_teacher_forcing_equivalence(name):
    cfg = CFGS[name]
    params, state, tokens = setup(cfg)
    ref_logits, _ = forward(params, state, tokens, cfg)  # [B,T,V]
    p0 = cfg.seq_len // 2
    _, last, outs, _ = run_decode(cfg, params, state, tokens, p0)
    np.testing.assert_allclose(np.asarray(last), np.asarray(ref_logits[:, p0 - 1]),
                               atol=1e-4, rtol=1e-4)
    for i, logits in enumerate(outs):
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref_logits[:, p0 + i]),
            atol=1e-4, rtol=1e-4, err_msg=f"{name} step {p0 + i}",
        )


@pytest.mark.parametrize("name", ["dense", "local", "fixed", "mosa_full"])
def test_teacher_forcing_equivalence_paged_permuted_table(name):
    """The paged regression twin of `test_teacher_forcing_equivalence`:
    prefill_paged + T×decode_step_paged through a page table in
    deliberately non-identity physical order must still match the score
    forward at 1e-4 — physical page placement is invisible to the math."""
    cfg = CFGS[name]
    params, state, tokens = setup(cfg)
    ref_logits, _ = forward(params, state, tokens, cfg)  # [B,T,V]
    cap = 32
    ps = 2 if cfg.window > 0 else 4  # >1 page per local ring too
    spec = dec.page_spec(cfg, B, cap, page_size=ps)
    rng = np.random.default_rng(23)
    table = np.array(dec.identity_page_table(spec, B))
    for e in spec["kinds"]:
        perm = rng.permutation(e["pool_pages"]).astype(np.int32)
        seg = table[:, e["row_offset"]:e["row_offset"] + e["pages_per_slot"]]
        table[:, e["row_offset"]:e["row_offset"] + e["pages_per_slot"]] = perm[seg]
    table = jnp.asarray(table)
    p0 = cfg.seq_len // 2
    prefill = dec.make_prefill_paged(cfg, cap, B, spec)
    step = dec.make_decode_step_paged(cfg, cap, B, spec)
    plen = jnp.full((B,), p0, jnp.int32)
    _, last, pools = prefill(params, state, tokens, plen, table)
    np.testing.assert_allclose(np.asarray(last), np.asarray(ref_logits[:, p0 - 1]),
                               atol=1e-4, rtol=1e-4)
    zero = jnp.zeros((B,), jnp.int32)
    for t in range(p0, cfg.seq_len):
        pos = jnp.full((B,), t, jnp.int32)
        logits, pools = step(params, state, tokens[:, t], pos, zero, table, pools)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref_logits[:, t]),
            atol=1e-4, rtol=1e-4, err_msg=f"{name} paged step {t}",
        )


def test_teacher_forcing_mosa_prefix_causal():
    """MoSA with k < T: the decode trace must agree with the *prefix-causal*
    streaming semantics. Verified where it is externally checkable: the
    layer-0 cache after consuming T tokens holds exactly the top-k of the
    layer-0 router scores (router inputs at layer 0 do not depend on the
    attention history), and every emitted logit is finite."""
    cfg = CFGS["mosa"]
    params, state, tokens = setup(cfg)
    _, _, outs, caches = run_decode(cfg, params, state, tokens, 1)
    for logits in outs:
        assert bool(jnp.all(jnp.isfinite(logits)))
    # layer-0 router scores, recomputed exactly as the model sees them
    x = params["emb"][tokens]  # [B,T,h]
    lp0 = params["layers"][0]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    xin = (x - mu) * jax.lax.rsqrt(var + 1e-5) * lp0["ln1"]["g"] + lp0["ln1"]["b"]
    r = jax.nn.sigmoid(jnp.einsum("bth,nh->bnt", xin, lp0["attn"]["sparse"]["wr"]))
    sel = r.at[:, :, 0].set(2.0)  # include_first sink
    want = jnp.sort(jnp.argsort(-sel, axis=-1)[..., : cfg.k_sel], axis=-1)
    got = jnp.sort(caches["layers"][0]["mosa_pos"], axis=-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mosa_sink_never_evicted():
    """include_first pins token 0 (priority 2 > sigma) for the whole run."""
    cfg = CFGS["mosa"]
    params, state, tokens = setup(cfg, seed=3)
    _, _, _, caches = run_decode(cfg, params, state, tokens, 1)
    for lc in caches["layers"]:
        assert bool(jnp.all(jnp.any(lc["mosa_pos"] == 0, axis=-1)))
        assert bool(jnp.all(jnp.max(lc["mosa_pri"], axis=-1) == 2.0))


# ---------------------------------------------------------------------------
# in-graph sampling (decode_step_sample)
# ---------------------------------------------------------------------------


def _host_sample_mirror(logits, uniforms, temp, k, k_max):
    """Reference mirror of rust decode::sample::sample_row_u: stable
    descending top-k (ties -> lower index), f32 weights
    exp((v - v0)/temp), *sequential* f32 cumsum, inverse-CDF draw."""
    out = []
    for row, u in zip(np.asarray(logits), np.asarray(uniforms)):
        order = sorted(range(len(row)), key=lambda i: (-row[i], i))[:k_max]
        kcl = min(max(int(k), 1), k_max)
        m = row[order[0]]
        t = np.float32(max(temp, 1e-4))
        acc = np.float32(0)
        cum = []
        for j, i in enumerate(order):
            w = np.float32(np.exp(np.float32((np.float32(row[i]) - m) / t))) if j < kcl else np.float32(0)
            acc = np.float32(acc + w)
            cum.append(acc)
        x = np.float32(u) * cum[-1]
        sel = next(j for j, c in enumerate(cum) if c >= x)
        out.append(order[sel])
    return np.array(out, np.int32)


def test_sample_from_logits_matches_sequential_host_mirror():
    """The in-graph sampler must agree token-for-token with the host-side
    sequential-f32 mirror (the Rust `sample_row_u` contract) given the
    same uniforms — XLA CPU's cumsum accumulates in the same order."""
    rng = np.random.default_rng(0)
    k_max = 32
    fn = jax.jit(lambda lg, u, t, k: dec.sample_from_logits(lg, u, t, k, k_max)[0])
    for trial in range(40):
        logits = (rng.normal(size=(8, 96)) * 3).astype(np.float32)
        u = rng.random(8).astype(np.float32)
        for k in (1, 4, 32):
            got = np.asarray(fn(logits, u, np.float32(0.9), np.int32(k)))
            want = _host_sample_mirror(logits, u, 0.9, k, k_max)
            np.testing.assert_array_equal(got, want, err_msg=f"trial {trial} k {k}")


def test_sample_greedy_k1_is_argmax_with_tie_break():
    logits = np.array([[1.0, 3.0, 3.0, 2.0], [0.5, 0.5, 0.5, 0.5]], np.float32)
    ids, vals, tidx = dec.sample_from_logits(
        jnp.asarray(logits), jnp.asarray([0.99, 0.01], jnp.float32),
        jnp.float32(1.0), jnp.int32(1), 4,
    )
    # ties break to the lower index, uniform ignored at k=1
    np.testing.assert_array_equal(np.asarray(ids), [1, 0])
    assert vals.shape == (2, 4) and tidx.shape == (2, 4)
    # top-k tail is sorted descending
    assert bool(jnp.all(vals[:, :-1] >= vals[:, 1:]))


def test_sample_ids_always_in_topk_support():
    rng = np.random.default_rng(3)
    logits = (rng.normal(size=(4, 64)) * 2).astype(np.float32)
    for k in (2, 5):
        for _ in range(20):
            u = rng.random(4).astype(np.float32)
            ids, _, tidx = dec.sample_from_logits(
                jnp.asarray(logits), jnp.asarray(u), jnp.float32(1.0), jnp.int32(k), 16
            )
            for b in range(4):
                assert int(ids[b]) in set(np.asarray(tidx)[b, :k].tolist())


def test_decode_sample_step_matches_decode_step():
    """decode_step_sample is decode_step + the fused sampling head: same
    cache trajectory, and its ids equal sampling the plain step's logits."""
    cfg = CFGS["mosa"]
    params, state, tokens = setup(cfg, seed=9)
    cap = 32
    step = dec.make_decode_step(cfg, cap, B)
    samp = dec.make_decode_sample(cfg, cap, B)
    prefill = dec.make_prefill(cfg, cap, B)
    _, _, c1 = prefill(params, state, tokens, jnp.full((B,), 4, jnp.int32))
    c2 = c1
    rng = np.random.default_rng(5)
    for t in range(4, 10):
        tok = tokens[:, t]
        pos = jnp.full((B,), t, jnp.int32)
        zero = jnp.zeros((B,), jnp.int32)
        u = jnp.asarray(rng.random(B), jnp.float32)
        logits, c1 = step(params, state, tok, pos, zero, c1)
        ids, _, _, c2 = samp(params, state, tok, pos, zero, u,
                             jnp.float32(0.8), jnp.int32(4), c2)
        ref, _, _ = dec.sample_from_logits(logits, u, jnp.float32(0.8),
                                           jnp.int32(4), dec.sample_k_max(cfg))
        np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref))
        for a, b in zip(jax.tree_util.tree_leaves(c1), jax.tree_util.tree_leaves(c2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# continuous-batching mechanics
# ---------------------------------------------------------------------------


def test_reset_invalidates_only_hot_slots():
    cfg = CFGS["dense"]
    params, state, tokens = setup(cfg)
    cap = 32
    prefill = dec.make_prefill(cfg, cap, B)
    plen = jnp.full((B,), 8, jnp.int32)
    _, _, caches = prefill(params, state, tokens, plen)
    step = dec.make_decode_step(cfg, cap, B)
    reset = jnp.array([1, 0], jnp.int32)  # admit a new sequence into slot 0
    pos = jnp.array([0, 8], jnp.int32)
    tok = jnp.array([5, 7], jnp.int32)
    _, nc = step(params, state, tok, pos, reset, caches)
    p = nc["layers"][0]["dense_pos"]
    # slot 0: everything invalidated except the newly written position 0
    assert bool(jnp.all(jnp.sort(p[0], axis=-1)[:, 0] == 0))
    assert bool(jnp.all(jnp.sort(p[0], axis=-1)[:, 1:] == dec.POS_SENTINEL))
    # slot 1: prefix survives plus the new position 8
    assert bool(jnp.any(p[1] == 8))
    assert bool(jnp.any(p[1] == 0))


def test_decode_after_reset_matches_fresh_sequence():
    """A slot admitted via reset must produce the same logits as the same
    tokens decoded in a never-used slot (no leakage from the evictee)."""
    cfg = CFGS["mosa"]
    params, state, tokens = setup(cfg, seed=5)
    cap = 32
    step = dec.make_decode_step(cfg, cap, B)
    prefill = dec.make_prefill(cfg, cap, B)
    # run A: prefill garbage, then reset slot 0 and decode tokens[0, :4]
    _, _, caches = prefill(params, state, tokens[:, ::-1], jnp.full((B,), 12, jnp.int32))
    outs_a = []
    for t in range(4):
        reset = jnp.array([1 if t == 0 else 0, 0], jnp.int32)
        pos = jnp.array([t, 12 + t], jnp.int32)
        tok = jnp.stack([tokens[0, t], tokens[1, t]])
        logits, caches = step(params, state, tok, pos, reset, caches)
        outs_a.append(logits[0])
    # run B: the same four tokens through a fresh cache (reset at step 0)
    _, _, fresh = prefill(params, state, tokens, jnp.full((B,), 1, jnp.int32))
    outs_b = []
    for t in range(4):
        reset = jnp.full((B,), 1 if t == 0 else 0, jnp.int32)
        pos = jnp.full((B,), t, jnp.int32)
        logits, fresh = step(params, state, tokens[:, t], pos, reset, fresh)
        outs_b.append(logits[0])
    for a, b in zip(outs_a, outs_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# AOT lowering of the decode programs
# ---------------------------------------------------------------------------


def test_lowered_decode_programs_and_manifest(tmp_path):
    """lower_variant with a decode spec emits prefill + decode_step HLO
    that reparses, and a manifest cache section whose leaves carry
    kind/init tags in canonical order."""
    from jax._src.lib import xla_client as xc

    from compile import aot, variants

    cfg = CFGS["mosa"]
    v = variants.Variant(
        name="t_dec", cfg=cfg, batch=B, programs=["score", "decode"],
        group="test", base_heads=2,
        decode=variants.DecodeSpec(capacity=32, extra_batches=(1,), extra_capacities=()),
    )
    entry = aot.lower_variant(v, str(tmp_path))
    progs = entry["programs"]
    assert set(progs) == {
        "score", "prefill", "decode_step", "decode_step_b1",
        "decode_step_sample", "decode_step_sample_b1",
        "prefill_paged", "decode_step_paged", "decode_step_paged_b1",
        "decode_step_sample_paged", "decode_step_sample_paged_b1",
        "prefill_qpaged", "decode_step_qpaged", "decode_step_qpaged_b1",
        "decode_step_sample_qpaged", "decode_step_sample_qpaged_b1",
    }
    for pname, prog in progs.items():
        assert prog["untupled"] is True
        text = open(tmp_path / prog["file"]).read()
        assert text.startswith("HloModule")
        assert "largest" not in text  # the 0.5.1-incompatible TopK attribute
        module = xc._xla.hlo_module_from_text(text)
        assert module is not None
    step = progs["decode_step"]
    assert step["batch"] == B and step["capacity"] == 32
    assert [e["name"] for e in step["extra_inputs"]] == ["token", "pos", "reset"]
    assert step["extra_outputs"][0]["shape"] == [B, cfg.vocab]
    names = [e["path"] for e in step["cache"]]
    assert names == [
        "layers[0].dense_k", "layers[0].dense_pos", "layers[0].dense_v",
        "layers[0].mosa_k", "layers[0].mosa_pos", "layers[0].mosa_pri", "layers[0].mosa_v",
        "layers[1].dense_k", "layers[1].dense_pos", "layers[1].dense_v",
        "layers[1].mosa_k", "layers[1].mosa_pos", "layers[1].mosa_pri", "layers[1].mosa_v",
    ]
    by = {e["path"]: e for e in step["cache"]}
    assert by["layers[0].dense_k"] == {
        "path": "layers[0].dense_k", "shape": [B, cfg.n_dense, 32, cfg.d_head],
        "dtype": "f32", "kind": "kv", "init": "zeros",
    }
    assert by["layers[0].mosa_pos"]["init"] == "sentinel"
    assert by["layers[0].mosa_pri"] == {
        "path": "layers[0].mosa_pri", "shape": [B, cfg.n_sparse, cfg.k_sel],
        "dtype": "f32", "kind": "meta", "init": "neg",
    }
    # decode_step input arity: model leaves + token/pos/reset + cache leaves
    text = open(tmp_path / step["file"]).read()
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    arity = sum(1 for l in lines[start:] if " parameter(" in l)
    n_model = entry["n_params_leaves"] + entry["n_state_leaves"]
    assert arity == n_model + 3 + len(step["cache"])
    # the batch-1 family scales every cache leaf's batch dim
    b1 = progs["decode_step_b1"]
    assert b1["batch"] == 1
    assert all(e["shape"][0] == 1 for e in b1["cache"])
    # donated sections: decode_step aliases cache input j -> output 1 + j
    # (after the logits), the sampling twin -> output 3 + j (after
    # ids/topk_vals/topk_ids), prefill donates nothing (cache is
    # output-only)
    n_cache = len(step["cache"])
    assert step["donated"]["aliases"] == [
        [n_model + 3 + j, 1 + j] for j in range(n_cache)
    ]
    samp = progs["decode_step_sample"]
    assert samp["donated"]["aliases"] == [
        [n_model + 6 + j, 3 + j] for j in range(n_cache)
    ]
    assert progs["prefill"]["donated"] == {"aliases": []}
    # the sampling twin's manifest surface
    assert samp["sample_k"] == min(32, cfg.vocab)
    assert [e["name"] for e in samp["extra_inputs"]] == [
        "token", "pos", "reset", "uniform", "temp", "k",
    ]
    assert [e["name"] for e in samp["extra_outputs"]] == ["ids", "topk_vals", "topk_ids"]
    assert samp["extra_outputs"][0] == {"name": "ids", "shape": [B], "dtype": "i32"}
    assert samp["extra_outputs"][1]["shape"] == [B, samp["sample_k"]]
    assert samp["cache"] == step["cache"]
    # the donating programs carry the alias clause in their HLO header
    text = open(tmp_path / step["file"]).read()
    assert "input_output_alias=" in text.splitlines()[0]
    assert aot.parse_alias_map(text) == step["donated"]["aliases"]


def test_core_variants_carry_decode_specs():
    from compile import variants

    core = {v.name: v for v in variants.core_variants()}
    for name in ("micro_dense", "micro_mosa_r8", "micro_fixed_r8", "micro_routing_r8"):
        assert "decode" in core[name].programs
        assert core[name].decode.capacity == variants.DECODE_CAPACITY
    assert core["micro_mosa_r8"].decode.extra_batches == (1, 32)
    assert core["micro_dense"].decode.extra_capacities == (128, 256, 512)


def test_streaming_topk_equals_prefix_topk():
    """The eviction rule (enter iff score > min cached priority) reproduces
    top-k over the prefix at every step — pure-python property check."""
    rng = np.random.default_rng(11)
    for _ in range(20):
        k = int(rng.integers(2, 6))
        scores = rng.random(24)
        cache = []  # list of (score, pos)
        for t, s in enumerate(scores):
            if len(cache) < k:
                cache.append((s, t))
            else:
                lo = min(range(k), key=lambda i: cache[i][0])
                if s > cache[lo][0]:
                    cache[lo] = (s, t)
            want = set(np.argsort(-scores[: t + 1], kind="stable")[:k].tolist())
            got = {p for _, p in cache}
            assert got == want
