"""L2 model-level invariants: shapes, causality, training dynamics,
parameter accounting, adaptive short-sequence scoring."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import ModelConfig, forward, init_params, loss_fn, token_logprobs
from compile.train import clip_by_global_norm, global_norm, make_init, make_score, make_train_step

jax.config.update("jax_platform_name", "cpu")

CFGS = {
    "dense": ModelConfig(vocab=64, d_model=32, d_head=8, d_ff=64, n_layers=2, seq_len=32, n_dense=2),
    "mosa": ModelConfig(vocab=64, d_model=32, d_head=8, d_ff=64, n_layers=2, seq_len=32,
                        n_dense=1, n_sparse=3, sparse_kind="mosa", k_sel=8),
    "fixed": ModelConfig(vocab=64, d_model=32, d_head=8, d_ff=64, n_layers=2, seq_len=32,
                         n_dense=1, n_sparse=3, sparse_kind="fixed", k_sel=8),
    "routing": ModelConfig(vocab=64, d_model=32, d_head=8, d_ff=64, n_layers=2, seq_len=32,
                           n_dense=1, n_sparse=2, sparse_kind="routing", k_sel=8),
    "local": ModelConfig(vocab=64, d_model=32, d_head=8, d_ff=64, n_layers=2, seq_len=32,
                         n_dense=2, window=8, n_sparse=2, sparse_kind="mosa", k_sel=8),
}


def batch(cfg, b=2, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, size=(b, cfg.seq_len + 1)), jnp.int32)


@pytest.mark.parametrize("name", list(CFGS))
def test_forward_shapes(name):
    cfg = CFGS[name]
    params, state = init_params(jax.random.PRNGKey(0), cfg)
    tok = batch(cfg)[:, :-1]
    logits, new_state = forward(params, state, tok, cfg)
    assert logits.shape == (2, cfg.seq_len, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_model_causality_dense():
    """Changing token t must not affect logits at positions < t for the
    dense model (strict autoregressive masking)."""
    cfg = CFGS["dense"]
    params, state = init_params(jax.random.PRNGKey(1), cfg)
    tok = batch(cfg, b=1, seed=2)[:, :-1]
    t_perturb = 20
    tok2 = tok.at[0, t_perturb].set((tok[0, t_perturb] + 1) % cfg.vocab)
    l1, _ = forward(params, state, tok, cfg)
    l2, _ = forward(params, state, tok2, cfg)
    np.testing.assert_allclose(
        l1[0, :t_perturb], l2[0, :t_perturb], atol=2e-5,
        err_msg="future token leaked into the past"
    )
    assert float(jnp.max(jnp.abs(l1[0, t_perturb:] - l2[0, t_perturb:]))) > 1e-4


def test_mosa_selection_is_non_autoregressive():
    """Paper Sec 5 (Limitations): expert-choice top-k is computed over the
    WHOLE sequence, so a future token can change which tokens a head
    selects — and thereby past logits — even though the attention mask
    itself never lets a query read a future key. This test documents that
    known property: the *mask* invariant holds (kernel tests), but strict
    end-to-end causality does not."""
    cfg = CFGS["mosa"]
    params, state = init_params(jax.random.PRNGKey(1), cfg)
    tok = batch(cfg, b=1, seed=2)[:, :-1]
    t_perturb = 20
    tok2 = tok.at[0, t_perturb].set((tok[0, t_perturb] + 1) % cfg.vocab)
    l1, _ = forward(params, state, tok, cfg)
    l2, _ = forward(params, state, tok2, cfg)
    past_delta = float(jnp.max(jnp.abs(l1[0, :t_perturb] - l2[0, :t_perturb])))
    assert past_delta > 0, (
        "expected the documented non-autoregressive selection effect; "
        "if this starts passing, the MoD-style autoregressive adaptation "
        "(paper future work) has been implemented — update the test"
    )


@pytest.mark.parametrize("name", list(CFGS))
def test_train_step_decreases_loss(name):
    cfg = CFGS[name]
    step = jax.jit(make_train_step(cfg))
    p, s, m, v, t = jax.jit(make_init(cfg))(jnp.int32(0))
    tok = batch(cfg, b=4, seed=3)
    losses = []
    for _ in range(25):
        p, s, m, v, t, loss = step(p, s, m, v, t, tok, jnp.float32(3e-3))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, f"{name}: {losses[0]} -> {losses[-1]}"
    assert float(t) == 25.0


def test_initial_loss_near_uniform():
    cfg = CFGS["mosa"]
    params, state = init_params(jax.random.PRNGKey(4), cfg)
    loss, _ = loss_fn(params, state, batch(cfg, seed=5), cfg)
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.3


def test_param_count_matches_flops_module():
    from compile import flops

    for name, cfg in CFGS.items():
        if cfg.window > 0:
            continue  # local preset shares dense head params
        params, _ = init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
        predicted = flops.model_params(
            cfg.n_layers, cfg.d_model, cfg.d_head, cfg.d_ff, cfg.vocab,
            cfg.n_dense, cfg.n_sparse, cfg.sparse_kind,
        )
        assert actual == predicted, f"{name}: {actual} != {predicted}"


def test_token_logprobs_are_log_probabilities():
    cfg = CFGS["mosa"]
    params, state = init_params(jax.random.PRNGKey(6), cfg)
    tok = batch(cfg, seed=7)
    lp = token_logprobs(params, state, tok, cfg)
    assert lp.shape == (2, cfg.seq_len)
    assert bool(jnp.all(lp <= 0))


def test_score_short_adaptive_k():
    """Sec 3.5: at short T the model scores with k = max(T/rho, 2)."""
    cfg = dataclasses.replace(CFGS["mosa"], seq_len=8, k_sel=2)
    params, state = init_params(jax.random.PRNGKey(8), CFGS["mosa"])
    score = make_score(cfg)
    rng = np.random.default_rng(9)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, 9)), jnp.int32)
    lp = score(params, state, tok)
    assert lp.shape == (1, 8)
    assert bool(jnp.all(jnp.isfinite(lp)))


def test_global_norm_clip():
    tree = {"a": jnp.asarray([3.0, 4.0]), "b": jnp.zeros(2)}
    assert abs(float(global_norm(tree)) - 5.0) < 1e-6
    clipped, n = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    # under the cap: untouched
    same, _ = clip_by_global_norm(tree, 10.0)
    np.testing.assert_allclose(same["a"], tree["a"])


def test_deterministic_init():
    cfg = CFGS["dense"]
    p1, _ = init_params(jax.random.PRNGKey(42), cfg)
    p2, _ = init_params(jax.random.PRNGKey(42), cfg)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(a, b)


def test_mosa_beats_dense_on_recall_task():
    """A miniature of the paper's thesis: on a synthetic recall task
    (predict a token declared earlier at a content-dependent position),
    FLOP-matched MoSA should learn at least as well as a smaller dense
    model. Smoke-scale: just assert MoSA trains to a reasonable loss."""
    cfg = CFGS["mosa"]
    step = jax.jit(make_train_step(cfg))
    p, s, m, v, t = jax.jit(make_init(cfg))(jnp.int32(1))
    rng = np.random.default_rng(10)
    # recall batch: [k, v, noise..., k] -> predict v
    def recall_batch():
        b = np.full((4, cfg.seq_len + 1), 0, dtype=np.int32)
        for i in range(4):
            key, val = rng.integers(1, 32), rng.integers(32, 63)
            b[i] = rng.integers(1, 32, size=cfg.seq_len + 1)
            b[i, 0], b[i, 1] = key, val
            b[i, -2], b[i, -1] = key, val
        return jnp.asarray(b)

    losses = []
    for _ in range(60):
        p, s, m, v, t, loss = step(p, s, m, v, t, recall_batch(), jnp.float32(3e-3))
        losses.append(float(loss))
    assert losses[-1] < losses[0], "MoSA failed to learn the recall task"
