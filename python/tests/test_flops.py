"""FLOP accounting vs the paper's printed numbers (Python side; the Rust
side asserts the same fixtures — the two implementations are mirrors)."""

import pytest

from compile import flops


def test_table4_exact():
    expect = {
        "tiny": 54_760_833_024,
        "small": 219_848_638_464,
        # paper prints 430.70G for Medium, but Medium is dimensionally
        # exactly 2x Small (18 vs 9 layers, same h/ff/heads) => 439.70G.
        # We assert the arithmetic truth; see EXPERIMENTS.md §Analytic.
        "medium": 439_697_276_928,
        "large": 1_130_650_140_672,
    }
    for name, want in expect.items():
        s = flops.PAPER_SIZES[name]
        got = flops.model_forward_flops(
            s["layers"], s["h"], s["hp"], s["d_ff"], flops.PAPER_T, s["heads"]
        )
        assert got == want, name


@pytest.mark.parametrize(
    "rho,heads",
    [(2, 13), (4, 31), (8, 69), (16, 142), (32, 276), (64, 505), (128, 848), (256, 1277)],
)
def test_table5_tiny_hybrid_heads(rho, heads):
    s = flops.PAPER_SIZES["tiny"]
    got = flops.solve_sparse_heads(
        s["h"], s["hp"], flops.PAPER_T, flops.PAPER_T // rho, s["heads"], 4, "mosa"
    )
    assert got == heads


@pytest.mark.parametrize("rho,heads", [(2, 23), (4, 56), (8, 124), (16, 255)])
def test_table5_tiny_pure_heads(rho, heads):
    s = flops.PAPER_SIZES["tiny"]
    got = flops.solve_sparse_heads(
        s["h"], s["hp"], flops.PAPER_T, flops.PAPER_T // rho, s["heads"], 0, "mosa"
    )
    assert got == heads


@pytest.mark.parametrize(
    "rho,params_m", [(2, 34), (4, 48), (8, 78), (16, 136), (32, 242), (64, 423)]
)
def test_table5_tiny_param_counts(rho, params_m):
    s = flops.PAPER_SIZES["tiny"]
    n = flops.solve_sparse_heads(
        s["h"], s["hp"], flops.PAPER_T, flops.PAPER_T // rho, s["heads"], 4, "mosa"
    )
    p = flops.model_params(
        s["layers"], s["h"], s["hp"], s["d_ff"], flops.PAPER_VOCAB, 4, n, "mosa"
    )
    assert round(p / 1e6) == params_m


def test_solver_budget_invariant():
    """Sparse attention FLOPs never exceed the dense baseline budget."""
    import itertools

    for h, t, rho, kind in itertools.product(
        [128, 512], [128, 1024], [2, 8, 32], ["mosa", "fixed", "routing"]
    ):
        hp, base, keep = 64, 9, 4
        k = max(t // rho, 2)
        n = flops.solve_sparse_heads(h, hp, t, k, base, keep, kind)
        budget = base * flops.dense_head_flops(h, hp, t)
        spent = keep * flops.dense_head_flops(h, hp, t) + n * flops.sparse_head_flops(
            kind, h, hp, t, k
        )
        assert spent <= budget
        over = keep * flops.dense_head_flops(h, hp, t) + (n + 1) * flops.sparse_head_flops(
            kind, h, hp, t, k
        )
        assert over > budget


def test_mosa_head_flops_formula_terms():
    # direct transcription check of App. A
    h, hp, t, k = 512, 64, 1024, 128
    want = 8 * h * hp * k + 4 * hp * k * k + 2 * h * t + hp * k
    assert flops.mosa_head_flops(h, hp, t, k) == want


def test_routing_equals_rho_decomposition():
    # App A: FLOP_routing = rho*(6hh'k + 4h'k^2) + 2h'T
    h, hp, t = 512, 64, 1024
    for rho in [2, 4, 8]:
        k = t // rho
        want = rho * (6 * h * hp * k + 4 * hp * k * k) + 2 * hp * t
        assert flops.routing_head_flops(h, hp, t, k) == want
