"""Paged KV-cache tests: the differential paged-vs-contiguous contract.

The paged decode programs (compile/decode.py §paged) must be
*bit-identical* to the contiguous programs whenever every logical page
the computation touches is backed — any page table, any physical order.
These tests pin that down across every head kind, plus the safety
property that makes host-side overcommit sound: writes through unbacked
(PAGE_SENTINEL) table entries drop instead of clobbering other slots'
pages, and unbacked reads are masked to the empty-slot values.

Schema tests mirror the PR 4 ``donated``-section tests: the manifest
``pages`` section must carry a geometry the Rust runtime can trust
blindly (divisibility, row partition, pool bounds, in-range identity
tables).
"""

import dataclasses

import jax
import jax.numpy as jnp
import jax.tree_util as jtu
import numpy as np
import pytest

from compile import decode as dec
from compile.model import ModelConfig, forward, init_params

jax.config.update("jax_platform_name", "cpu")

B = 2
CAP = 32


def make_cfg(**kw):
    base = dict(
        vocab=48, d_model=16, d_head=8, d_ff=32, n_layers=2, seq_len=16,
        n_dense=2, window=0, n_sparse=0, sparse_kind="none", k_sel=0,
        use_kernel=False,
    )
    base.update(kw)
    return ModelConfig(**base)


CFGS = {
    "dense": make_cfg(),
    "local": make_cfg(window=4),
    "mosa": make_cfg(n_dense=1, n_sparse=2, sparse_kind="mosa", k_sel=4),
    "fixed": make_cfg(n_dense=1, n_sparse=2, sparse_kind="fixed", k_sel=4),
    "routing": make_cfg(n_dense=1, n_sparse=2, sparse_kind="routing", k_sel=4),
}


def setup(cfg, seed=0):
    params, state = init_params(jax.random.PRNGKey(seed), cfg)
    tokens = jax.random.randint(
        jax.random.PRNGKey(seed + 1), (B, cfg.seq_len), 0, cfg.vocab
    )
    return params, state, tokens.astype(jnp.int32)


def empty_caches(cfg, cap=CAP):
    """Contiguous caches in their init state (the KvCacheBuffers image)."""
    flat, treedef = jtu.tree_flatten_with_path(dec.cache_struct(cfg, B, cap))

    def initleaf(path, leaf):
        meta = dec.leaf_meta(str(path[-1]).strip("[']"))
        if meta["init"] == "sentinel":
            return jnp.full(leaf.shape, dec.POS_SENTINEL, leaf.dtype)
        if meta["init"] == "neg":
            return jnp.full(leaf.shape, -1.0, leaf.dtype)
        return jnp.zeros(leaf.shape, leaf.dtype)

    return jtu.tree_unflatten(treedef, [initleaf(p, l) for p, l in flat])


def permuted_table(spec, seed=7):
    """A fully-backed table in deliberately non-identity physical order:
    each kind's pool rows are permuted by a seeded permutation."""
    rng = np.random.default_rng(seed)
    table = np.array(dec.identity_page_table(spec, B))
    for e in spec["kinds"]:
        perm = rng.permutation(e["pool_pages"]).astype(np.int32)
        seg = table[:, e["row_offset"]:e["row_offset"] + e["pages_per_slot"]]
        table[:, e["row_offset"]:e["row_offset"] + e["pages_per_slot"]] = perm[seg]
    return jnp.asarray(table)


# ---------------------------------------------------------------------------
# pages geometry / schema invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(CFGS))
def test_page_spec_schema_invariants(name):
    cfg = CFGS[name]
    for page_size in (None, 4):
        spec = dec.page_spec(cfg, B, CAP, page_size=page_size, pool_frac=0.5)
        assert spec["sentinel"] == dec.PAGE_SENTINEL
        ps = spec["page_size"]
        assert ps >= 1
        # the kinds partition the page_index row contiguously
        off = 0
        for e in spec["kinds"]:
            assert e["row_offset"] == off
            off += e["pages_per_slot"]
            # page_size divides every kind's per-slot capacity
            assert e["slots"] % ps == 0
            assert e["pages_per_slot"] == e["slots"] // ps
            # one full-capacity sequence always fits
            assert e["pool_pages"] >= e["pages_per_slot"]
            if e["lazy"]:
                # lazy pools never exceed the contiguous worst case
                assert e["pool_pages"] <= B * e["pages_per_slot"]
            else:
                # bounded kinds cover worst-case admission exactly:
                # every slot can hold its whole (tiny) cache
                assert e["pool_pages"] == B * e["pages_per_slot"]
        assert off == spec["pages_per_slot"]


@pytest.mark.parametrize("name", list(CFGS))
def test_identity_table_indices_in_range(name):
    cfg = CFGS[name]
    spec = dec.page_spec(cfg, B, CAP, page_size=4)  # pool_frac 1: fully backed
    table = np.asarray(dec.identity_page_table(spec, B))
    for e in spec["kinds"]:
        seg = table[:, e["row_offset"]:e["row_offset"] + e["pages_per_slot"]]
        assert seg.min() >= 0 and seg.max() < e["pool_pages"]
        # no physical page mapped twice
        assert len(np.unique(seg)) == seg.size


def test_page_spec_rejects_nondividing_page_size():
    with pytest.raises(AssertionError):
        dec.page_spec(CFGS["mosa"], B, CAP, page_size=3)


def test_default_page_size_divides_and_caps():
    for name, cfg in CFGS.items():
        ps = dec.default_page_size(cfg, 1024)
        assert ps <= dec.DEFAULT_PAGE_CAP
        for _, slots, _ in dec.page_kinds(cfg, 1024):
            assert slots % ps == 0, name


def test_pool_shapes_match_logical_capacity():
    """Pool leaves regroup exactly the logical slots: pool_pages ×
    page_size elements per (head, dim) — and the lazy pools shrink by
    pool_frac while bounded pools don't."""
    cfg = CFGS["mosa"]
    spec = dec.page_spec(cfg, B, CAP, page_size=4, pool_frac=0.5)
    contiguous = dec.cache_shapes(cfg, B, CAP)
    paged = dec.paged_cache_shapes(cfg, B, CAP, spec)
    assert set(paged) == set(contiguous)
    for nm, leaf in paged.items():
        e = [k for k in spec["kinds"] if k["kind"] == nm.split("_")[0]][0]
        assert leaf.shape[0] == e["pool_pages"]
        assert leaf.shape[2] == spec["page_size"]
        assert leaf.shape[1] == contiguous[nm].shape[1]
    dense_k = paged["dense_k"]
    # 0.5 pool_frac on the lazy dense pool: half the contiguous slots
    assert dense_k.shape[0] * dense_k.shape[2] == B * CAP // 2
    mosa_k = paged["mosa_k"]
    assert mosa_k.shape[0] * mosa_k.shape[2] == B * cfg.k_sel


# ---------------------------------------------------------------------------
# the differential contract: paged == contiguous, bitwise
# ---------------------------------------------------------------------------


def run_pair(cfg, table_fn, page_size=4, pool_frac=1.0, p0=4, seed=0):
    """Drive prefill + teacher-forced decode through both layouts on the
    same weights/tokens; returns (contiguous logits, paged logits,
    contiguous caches, gathered paged caches, table)."""
    params, state, tokens = setup(cfg, seed)
    spec = dec.page_spec(cfg, B, CAP, page_size=page_size, pool_frac=pool_frac)
    table = table_fn(spec)
    prefill = dec.make_prefill(cfg, CAP, B)
    step = dec.make_decode_step(cfg, CAP, B)
    prefill_p = dec.make_prefill_paged(cfg, CAP, B, spec)
    step_p = dec.make_decode_step_paged(cfg, CAP, B, spec)
    plen = jnp.full((B,), p0, jnp.int32)
    lps_c, last_c, caches = prefill(params, state, tokens, plen)
    lps_p, last_p, pools = prefill_p(params, state, tokens, plen, table)
    np.testing.assert_array_equal(np.asarray(lps_c), np.asarray(lps_p))
    np.testing.assert_array_equal(np.asarray(last_c), np.asarray(last_p))
    zero = jnp.zeros((B,), jnp.int32)
    outs_c, outs_p = [], []
    for t in range(p0, cfg.seq_len):
        pos = jnp.full((B,), t, jnp.int32)
        lc, caches = step(params, state, tokens[:, t], pos, zero, caches)
        lp, pools = step_p(params, state, tokens[:, t], pos, zero, table, pools)
        outs_c.append(np.asarray(lc))
        outs_p.append(np.asarray(lp))
    gathered = dec.gather_pools(spec, pools, table)
    return outs_c, outs_p, caches, gathered, table


@pytest.mark.parametrize("name", list(CFGS))
def test_paged_decode_bit_identical_identity_table(name):
    cfg = CFGS[name]
    ps = 4 if name != "local" else 2  # window 4: exercise >1 page per ring
    outs_c, outs_p, caches, gathered, _ = run_pair(
        cfg, lambda s: dec.identity_page_table(s, B), page_size=ps
    )
    for t, (a, b) in enumerate(zip(outs_c, outs_p)):
        np.testing.assert_array_equal(a, b, err_msg=f"{name} step {t}")
    # cache *payloads* (and metadata) identical through the page table
    for a, b in zip(jtu.tree_leaves(caches), jtu.tree_leaves(gathered)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


@pytest.mark.parametrize("name", list(CFGS))
def test_paged_decode_bit_identical_permuted_table(name):
    """Physical placement must be invisible: a permuted (non-identity)
    table yields bit-identical logits and logical cache contents."""
    cfg = CFGS[name]
    outs_c, outs_p, caches, gathered, table = run_pair(
        cfg, lambda s: permuted_table(s, seed=11), page_size=4 if name != "local" else 2
    )
    # the permutation is actually non-identity somewhere
    spec = dec.page_spec(cfg, B, CAP, page_size=4 if name != "local" else 2)
    assert not np.array_equal(np.asarray(table), np.asarray(dec.identity_page_table(spec, B)))
    for t, (a, b) in enumerate(zip(outs_c, outs_p)):
        np.testing.assert_array_equal(a, b, err_msg=f"{name} step {t}")
    for a, b in zip(jtu.tree_leaves(caches), jtu.tree_leaves(gathered)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


def test_unbacked_pages_never_clobber_backed_slots():
    """Overcommit safety: a slot whose lazy pages are unbacked
    (PAGE_SENTINEL) drops every write; the backed slot's logits stay
    bit-identical to a contiguous run, and the pools are untouched where
    nothing was mapped."""
    cfg = CFGS["mosa"]
    params, state, tokens = setup(cfg, seed=3)
    spec = dec.page_spec(cfg, B, CAP, page_size=4, pool_frac=0.5)
    dense = [e for e in spec["kinds"] if e["kind"] == "dense"][0]
    mosa = [e for e in spec["kinds"] if e["kind"] == "mosa"][0]
    assert dense["pool_pages"] == B * dense["pages_per_slot"] // 2  # overcommitted
    table = np.full((B, spec["pages_per_slot"]), dec.PAGE_SENTINEL, np.int32)
    # slot 0 fully backed; slot 1's dense pages left unbacked
    table[0, dense["row_offset"]:dense["row_offset"] + dense["pages_per_slot"]] = np.arange(
        dense["pages_per_slot"], dtype=np.int32
    )
    for b in range(B):
        o = mosa["row_offset"]
        table[b, o:o + mosa["pages_per_slot"]] = np.arange(
            b * mosa["pages_per_slot"], (b + 1) * mosa["pages_per_slot"], dtype=np.int32
        )
    table = jnp.asarray(table)
    step_p = dec.make_decode_step_paged(cfg, CAP, B, spec)
    step = dec.make_decode_step(cfg, CAP, B)
    pools = dec.init_pools(cfg, B, CAP, spec)
    caches = empty_caches(cfg)
    reset = jnp.asarray([1, 1], jnp.int32)
    for t in range(6):
        pos = jnp.full((B,), t, jnp.int32)
        lp, pools = step_p(params, state, tokens[:, t], pos, reset, table, pools)
        lc, caches = step(params, state, tokens[:, t], pos, reset, caches)
        # the backed slot is exact despite its neighbour's dropped writes
        np.testing.assert_array_equal(np.asarray(lp[0]), np.asarray(lc[0]), err_msg=str(t))
        assert bool(jnp.all(jnp.isfinite(lp)))
        reset = jnp.zeros((B,), jnp.int32)


def test_park_and_readmit_replay_matches_fresh_run():
    """The runtime's evict-and-readmit story, in-graph half: park a slot
    (its pages go back to the pool and get recycled by another slot),
    then re-admit it on fresh pages with reset + replay — the replayed
    slot's logits equal a contiguous run of the same stream."""
    cfg = CFGS["mosa"]
    params, state, tokens = setup(cfg, seed=5)
    spec = dec.page_spec(cfg, B, CAP, page_size=4, pool_frac=0.5)
    dense = [e for e in spec["kinds"] if e["kind"] == "dense"][0]
    mosa = [e for e in spec["kinds"] if e["kind"] == "mosa"][0]
    step_p = dec.make_decode_step_paged(cfg, CAP, B, spec)
    step = dec.make_decode_step(cfg, CAP, B)
    pools = dec.init_pools(cfg, B, CAP, spec)

    def tab(slot0_dense, slot1_dense):
        t = np.full((B, spec["pages_per_slot"]), dec.PAGE_SENTINEL, np.int32)
        for b, pages in ((0, slot0_dense), (1, slot1_dense)):
            if pages is not None:
                o = dense["row_offset"]
                t[b, o:o + len(pages)] = np.asarray(pages, np.int32)
            o = mosa["row_offset"]
            t[b, o:o + mosa["pages_per_slot"]] = np.arange(
                b * mosa["pages_per_slot"], (b + 1) * mosa["pages_per_slot"], dtype=np.int32
            )
        return jnp.asarray(t)

    npages = dense["pages_per_slot"]
    half = list(range(npages // 2))
    # phase 1: slot 0 runs on dense pages [0..half); slot 1 idle/unbacked
    table = tab(half, None)
    reset = jnp.asarray([1, 1], jnp.int32)
    for t in range(4):
        pos = jnp.asarray([t, 0], jnp.int32)
        _, pools = step_p(params, state, tokens[:, t], pos, reset, table, pools)
        reset = jnp.zeros((B,), jnp.int32)
    # phase 2: slot 0 parked — its pages are recycled INTO slot 1, which
    # admits (reset) and runs its own stream over the same physical rows
    table = tab(None, half)
    reset = jnp.asarray([1, 1], jnp.int32)
    for t in range(4):
        pos = jnp.asarray([0, t], jnp.int32)
        _, pools = step_p(params, state, tokens[:, ::-1][:, t], pos, reset, table, pools)
        reset = jnp.zeros((B,), jnp.int32)
    # phase 3: slot 0 re-admitted on the *other* pages, replaying its
    # stream from scratch; slot 1 keeps generating
    other = list(range(npages // 2, npages))
    table = tab(other, half)
    outs_replay = []
    reset = jnp.asarray([1, 0], jnp.int32)
    for t in range(6):
        pos = jnp.asarray([t, 4 + t], jnp.int32)
        tok = jnp.stack([tokens[0, t], tokens[:, ::-1][1, 4 + t]])
        lp, pools = step_p(params, state, tok, pos, reset, table, pools)
        outs_replay.append(np.asarray(lp[0]))
        reset = jnp.zeros((B,), jnp.int32)
    # reference: the same slot-0 stream through a fresh contiguous cache
    caches = empty_caches(cfg)
    reset = jnp.asarray([1, 1], jnp.int32)
    outs_ref = []
    for t in range(6):
        pos = jnp.full((B,), t, jnp.int32)
        lc, caches = step(params, state, tokens[:, t], pos, reset, caches)
        outs_ref.append(np.asarray(lc[0]))
        reset = jnp.zeros((B,), jnp.int32)
    for t, (a, b) in enumerate(zip(outs_replay, outs_ref)):
        np.testing.assert_array_equal(a, b, err_msg=f"replayed step {t}")


def test_paged_sample_step_matches_contiguous_sample_step():
    """decode_step_sample_paged: same ids and cache trajectory as the
    contiguous sampling twin given the same uniforms."""
    cfg = CFGS["mosa"]
    params, state, tokens = setup(cfg, seed=9)
    spec = dec.page_spec(cfg, B, CAP, page_size=4)
    table = permuted_table(spec, seed=13)
    samp_c = dec.make_decode_sample(cfg, CAP, B)
    samp_p = dec.make_decode_sample_paged(cfg, CAP, B, spec)
    prefill = dec.make_prefill(cfg, CAP, B)
    prefill_p = dec.make_prefill_paged(cfg, CAP, B, spec)
    plen = jnp.full((B,), 4, jnp.int32)
    _, _, caches = prefill(params, state, tokens, plen)
    _, _, pools = prefill_p(params, state, tokens, plen, table)
    rng = np.random.default_rng(5)
    zero = jnp.zeros((B,), jnp.int32)
    for t in range(4, 10):
        pos = jnp.full((B,), t, jnp.int32)
        u = jnp.asarray(rng.random(B), jnp.float32)
        ids_c, tv_c, ti_c, caches = samp_c(
            params, state, tokens[:, t], pos, zero, u, jnp.float32(0.8), jnp.int32(4), caches
        )
        ids_p, tv_p, ti_p, pools = samp_p(
            params, state, tokens[:, t], pos, zero, u, jnp.float32(0.8), jnp.int32(4),
            table, pools
        )
        np.testing.assert_array_equal(np.asarray(ids_c), np.asarray(ids_p))
        np.testing.assert_array_equal(np.asarray(tv_c), np.asarray(tv_p))
        np.testing.assert_array_equal(np.asarray(ti_c), np.asarray(ti_p))
    gathered = dec.gather_pools(spec, pools, table)
    for a, b in zip(jtu.tree_leaves(caches), jtu.tree_leaves(gathered)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# AOT lowering of the paged programs + pages manifest schema
# ---------------------------------------------------------------------------


def test_lowered_paged_programs_and_pages_manifest(tmp_path):
    """lower_variant emits the paged twins with a `pages` section the
    Rust runtime can validate blindly, and the paged HLO reparses through
    the pinned converter — mirroring the PR 4 donated-section tests."""
    from jax._src.lib import xla_client as xc

    from compile import aot, variants

    cfg = CFGS["mosa"]
    v = variants.Variant(
        name="t_paged", cfg=cfg, batch=B, programs=["decode"],
        group="test", base_heads=2,
        decode=variants.DecodeSpec(
            capacity=CAP, extra_batches=(1,), extra_capacities=(),
            page_size=4, pool_frac=0.5,
        ),
    )
    entry = aot.lower_variant(v, str(tmp_path))
    progs = entry["programs"]
    assert {
        "prefill_paged", "decode_step_paged", "decode_step_sample_paged",
        "decode_step_paged_b1", "decode_step_sample_paged_b1",
    } <= set(progs)
    n_model = entry["n_params_leaves"] + entry["n_state_leaves"]
    step = progs["decode_step_paged"]
    pages = step["pages"]
    # schema: geometry the Rust PageAllocator trusts
    assert pages["page_size"] == 4
    assert pages["sentinel"] == dec.PAGE_SENTINEL
    off = 0
    for e in pages["kinds"]:
        assert e["row_offset"] == off
        off += e["pages_per_slot"]
        assert e["slots"] % pages["page_size"] == 0
        assert e["pool_pages"] >= e["pages_per_slot"]
        if not e["lazy"]:
            assert e["pool_pages"] == step["batch"] * e["pages_per_slot"]
    assert off == pages["pages_per_slot"]
    # page_index is the last extra input, [batch, pages_per_slot] i32
    pi = step["extra_inputs"][-1]
    assert pi == {
        "name": "page_index", "shape": [B, pages["pages_per_slot"]], "dtype": "i32",
    }
    # pool leaves: [pool_pages, n, page_size(, d)] per kind, kind/init tags kept
    by = {e["path"]: e for e in step["cache"]}
    dense = [e for e in pages["kinds"] if e["kind"] == "dense"][0]
    assert by["layers[0].dense_k"]["shape"] == [
        dense["pool_pages"], cfg.n_dense, 4, cfg.d_head
    ]
    assert by["layers[0].dense_k"]["kind"] == "kv"
    assert by["layers[0].mosa_pos"]["init"] == "sentinel"
    assert by["layers[0].mosa_pri"]["init"] == "neg"
    # donated aliases: pools donate leaf-for-leaf after the page_index input
    n_cache = len(step["cache"])
    assert step["donated"]["aliases"] == [
        [n_model + 4 + j, 1 + j] for j in range(n_cache)
    ]
    samp = progs["decode_step_sample_paged"]
    assert samp["donated"]["aliases"] == [
        [n_model + 7 + j, 3 + j] for j in range(n_cache)
    ]
    assert samp["pages"] == pages
    assert [e["name"] for e in samp["extra_inputs"]] == [
        "token", "pos", "reset", "uniform", "temp", "k", "page_index",
    ]
    # prefill_paged: pages section present, cache output-only (no donation)
    ppf = progs["prefill_paged"]
    assert ppf["pages"] == pages
    assert ppf["donated"] == {"aliases": []}
    assert [e["name"] for e in ppf["extra_inputs"]] == ["tokens", "plen", "page_index"]
    # contiguous twins survive unchanged, without a pages section
    assert "pages" not in progs["decode_step"]
    assert "pages" not in progs["prefill"]
    # all paged HLO reparses through the pinned converter; donating
    # programs carry the alias clause
    for name in ["prefill_paged", "decode_step_paged", "decode_step_sample_paged"]:
        text = open(tmp_path / progs[name]["file"]).read()
        assert text.startswith("HloModule")
        assert "largest" not in text
        assert xc._xla.hlo_module_from_text(text) is not None
        if name != "prefill_paged":
            assert "input_output_alias=" in text.splitlines()[0]
            assert aot.parse_alias_map(text) == progs[name]["donated"]["aliases"]
    # the b1 family rescales the bounded pools and the table rows
    b1 = progs["decode_step_paged_b1"]
    assert b1["batch"] == 1
    assert b1["extra_inputs"][-1]["shape"] == [1, b1["pages"]["pages_per_slot"]]
    for e in b1["pages"]["kinds"]:
        if not e["lazy"]:
            assert e["pool_pages"] == e["pages_per_slot"]


def test_core_decode_specs_carry_paging():
    from compile import variants

    core = {v.name: v for v in variants.core_variants()}
    for name in ("micro_dense", "micro_mosa_r8", "micro_fixed_r8", "micro_routing_r8"):
        d = core[name].decode
        assert d.pool_frac < 1.0, "bench variants must exercise overcommit"
        spec = dec.page_spec(core[name].cfg, core[name].batch, d.capacity,
                             page_size=d.page_size, pool_frac=d.pool_frac)
        # the acceptance headline: paged resident payload ≤ half the
        # contiguous worst case for the capacity-sized kinds
        lazy = [e for e in spec["kinds"] if e["lazy"]]
        assert lazy, name
        for e in lazy:
            assert e["pool_pages"] * 2 <= core[name].batch * e["pages_per_slot"], name
