"""AOT surface tests: variant matrix sanity, manifest layout consistency,
HLO-text round-trip through the pinned xla_client (the same converter the
Rust loader's XLA uses)."""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, flops, variants
from compile.model import ModelConfig
from compile.train import make_init, make_score, make_train_step

jax.config.update("jax_platform_name", "cpu")


def test_variant_matrix_isoflop_invariant():
    """Every sparse variant's attention FLOPs stay within the dense
    baseline budget of its preset."""
    for v in variants.get_set("all"):
        cfg = v.cfg
        if cfg.n_sparse == 0:
            continue
        budget = v.base_heads * flops.dense_head_flops(cfg.d_model, cfg.d_head, cfg.seq_len)
        dense_cost = (
            flops.local_head_flops(cfg.d_model, cfg.d_head, cfg.seq_len, cfg.window)
            if cfg.window > 0
            else flops.dense_head_flops(cfg.d_model, cfg.d_head, cfg.seq_len)
        )
        spent = cfg.n_dense * dense_cost + cfg.n_sparse * flops.sparse_head_flops(
            cfg.sparse_kind, cfg.d_model, cfg.d_head, cfg.seq_len, cfg.k_sel, cfg.window
        )
        if v.group == "longseq" and cfg.seq_len > 256:
            continue  # heads intentionally held constant as T grows (Fig 4)
        assert spent <= budget, v.name


def test_variant_names_unique():
    names = [v.name for v in variants.get_set("all")]
    assert len(names) == len(set(names))


def test_short_cfg_adaptive_k():
    v = [x for x in variants.get_set("core") if x.name == "micro_mosa_r8"][0]
    scfg = v.short_cfg()
    assert scfg.seq_len == variants.SHORT_T
    assert scfg.k_sel == max(variants.SHORT_T // v.cfg.attn_spec().rho, 2)


def test_init_spec_rules():
    assert aot._init_spec("params", "layers.0.ln1.g") == "ones"
    assert aot._init_spec("params", "layers.0.ffn.b1") == "zeros"
    assert aot._init_spec("params", "emb") == "normal:0.02"
    assert aot._init_spec("m", "anything") == "zeros"
    assert aot._init_spec("state", "layers.0.centroids") == "centroid"


@pytest.fixture(scope="module")
def tiny_variant(tmp_path_factory):
    """Lower a truly tiny variant end-to-end and return (entry, dir)."""
    out = tmp_path_factory.mktemp("artifacts")
    cfg = ModelConfig(vocab=32, d_model=16, d_head=8, d_ff=32, n_layers=1, seq_len=16,
                      n_dense=1, n_sparse=2, sparse_kind="mosa", k_sel=4)
    v = variants.Variant(name="t_test", cfg=cfg, batch=2,
                         programs=["train", "score"], group="test", base_heads=2)
    entry = aot.lower_variant(v, str(out))
    return entry, out


def test_lowered_files_exist_and_parse(tiny_variant):
    entry, out = tiny_variant
    for prog in entry["programs"].values():
        p = os.path.join(out, prog["file"])
        assert os.path.exists(p)
        text = open(p).read()
        assert text.startswith("HloModule")
        assert "largest" not in text  # the 0.5.1-incompatible attribute


def test_manifest_layout_counts(tiny_variant):
    entry, _ = tiny_variant
    n_leaves = sum(len(entry["sections"][s]) for s in ["params", "state", "m", "v", "t"])
    assert entry["n_train_leaves"] == n_leaves
    assert entry["n_params_leaves"] == len(entry["sections"]["params"])
    # m and v mirror params exactly
    assert [l["shape"] for l in entry["sections"]["m"]] == [
        l["shape"] for l in entry["sections"]["params"]
    ]
    # every leaf has an init rule
    for sec in ["params", "state", "m", "v", "t"]:
        for l in entry["sections"][sec]:
            assert l["init"] in ("zeros", "ones", "centroid") or l["init"].startswith("normal:")


def test_n_params_matches_flops(tiny_variant):
    entry, _ = tiny_variant
    cfg = entry["config"]
    predicted = flops.model_params(
        cfg["n_layers"], cfg["d_model"], cfg["d_head"], cfg["d_ff"], cfg["vocab"],
        cfg["n_dense"], cfg["n_sparse"], cfg["sparse_kind"],
    )
    assert entry["n_params"] == predicted


def test_hlo_text_reparses(tiny_variant):
    """The lowered HLO text must re-parse through xla_client's HLO parser
    (the Rust engine's `HloModuleProto::from_text_file` uses the same
    grammar; end-to-end execution is covered by rust/tests/)."""
    from jax._src.lib import xla_client as xc

    entry, out = tiny_variant
    path = os.path.join(out, entry["programs"]["train"]["file"])
    text = open(path).read()
    module = xc._xla.hlo_module_from_text(text)
    assert module is not None
    # entry parameter arity = train-state leaves + batch + lr. The entry
    # computation's parameters appear as `%Arg_K` / `parameter(K)` lines
    # after the `ENTRY` header.
    n_expected = entry["n_train_leaves"] + 2
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    arity = sum(1 for l in lines[start:] if " parameter(" in l)
    assert arity == n_expected, f"{arity} != {n_expected}"


def test_parse_alias_map_header_forms():
    hdr = ("HloModule jit_step, input_output_alias={ {0}: (0, {}, may-alias), "
           "{2}: (5, {}, must-alias) }, entry_computation_layout={()->()}")
    assert aot.parse_alias_map(hdr + "\n\nENTRY main {}") == [[0, 0], [5, 2]]
    # single-output form: empty tuple index means output 0
    hdr1 = "HloModule m, input_output_alias={ {}: (1, {}, may-alias) }"
    assert aot.parse_alias_map(hdr1) == [[1, 0]]
    assert aot.parse_alias_map("HloModule m, entry_computation_layout={()->()}") == []


def test_train_program_donates_full_state(tiny_variant):
    """train is lowered with donate_argnums over params/state/m/v/t: the
    alias map must be the identity over every train-state leaf (input i
    aliases output i), so the Rust runtime can step the state in place."""
    entry, out = tiny_variant
    d = entry["programs"]["train"]["donated"]
    n = entry["n_train_leaves"]
    assert d["aliases"] == [[i, i] for i in range(n)]
    text = open(os.path.join(out, entry["programs"]["train"]["file"])).read()
    assert "input_output_alias=" in text.splitlines()[0]
    assert aot.parse_alias_map(text) == d["aliases"]
    # the batch/lr extra inputs and the loss output stay unaliased
    ins = {i for i, _ in d["aliases"]}
    outs = {o for _, o in d["aliases"]}
    assert n not in ins and n + 1 not in ins and n not in outs


def test_score_program_not_donated(tiny_variant):
    """score takes the model read-only: no donation, no alias header."""
    entry, out = tiny_variant
    assert "donated" not in entry["programs"]["score"]
    text = open(os.path.join(out, entry["programs"]["score"]["file"])).read()
    assert "input_output_alias=" not in text.splitlines()[0]


def test_perf_set_has_kernel_ablation_pair():
    vs = {v.name: v for v in variants.get_set("perf")}
    assert vs["micro_mosa_r8_nokernel"].cfg.use_kernel is False
    # the ppl-matched Table 2 config keeps fewer sparse heads than the
    # FLOP-matched sweep config
    flop_matched = {v.name: v for v in variants.get_set("core")}["micro_mosa_r8"]
    assert vs["micro_mosa_r8_match"].cfg.n_sparse < flop_matched.cfg.n_sparse
    assert vs["micro_mosa_r8_match"].cfg.k_sel == flop_matched.cfg.k_sel
