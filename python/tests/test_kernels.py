"""L1 correctness: the Pallas attention kernels vs the pure-jnp oracle.

This is the core correctness signal of the compile path — hypothesis
sweeps shapes, sparsity patterns and windows, and checks both the forward
values and the custom-vjp backward against jax.grad of the oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property-testing dep not in this container")
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ref

jax.config.update("jax_platform_name", "cpu")

ATOL = 2e-5


def rand_qkv(rng, n, tq, tk, d):
    q = jnp.asarray(rng.normal(size=(n, tq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(n, tk, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, tk, d)), jnp.float32)
    return q, k, v


def sparse_positions(rng, n, count, t_total):
    """Sorted unique positions per head (mimics expert-choice selections)."""
    out = np.stack([
        np.sort(rng.choice(t_total, size=count, replace=False)) for _ in range(n)
    ])
    return jnp.asarray(out, jnp.int32)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 4),
    tq=st.sampled_from([4, 8, 16, 64]),
    d=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_causal_matches_ref(n, tq, d, seed):
    rng = np.random.default_rng(seed)
    q, k, v = rand_qkv(rng, n, tq, tq, d)
    pos = jnp.broadcast_to(jnp.arange(tq, dtype=jnp.int32), (n, tq))
    got = attention(q, k, v, pos, pos)
    want = ref.ref_attention(q, k, v, pos, pos)
    np.testing.assert_allclose(got, want, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 4),
    ksel=st.sampled_from([2, 4, 8, 16]),
    d=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sparse_selected_positions_match_ref(n, ksel, d, seed):
    """MoSA-style: both sides indexed by the same selected positions."""
    rng = np.random.default_rng(seed)
    q, k, v = rand_qkv(rng, n, ksel, ksel, d)
    idx = sparse_positions(rng, n, ksel, 128)
    got = attention(q, k, v, idx, idx)
    want = ref.ref_attention(q, k, v, idx, idx)
    np.testing.assert_allclose(got, want, atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(
    window=st.sampled_from([1, 4, 16]),
    tq=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_local_window_matches_ref(window, tq, seed):
    rng = np.random.default_rng(seed)
    q, k, v = rand_qkv(rng, 2, tq, tq, 8)
    pos = jnp.broadcast_to(jnp.arange(tq, dtype=jnp.int32), (2, tq))
    got = attention(q, k, v, pos, pos, None, window)
    want = ref.ref_attention(q, k, v, pos, pos, None, window)
    np.testing.assert_allclose(got, want, atol=ATOL)


def test_causality_no_future_leakage():
    """Perturbing key/value at position j must not change outputs at
    queries with position < j (the index-aware mask invariant)."""
    rng = np.random.default_rng(0)
    n, t, d = 1, 16, 8
    q, k, v = rand_qkv(rng, n, t, t, d)
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (n, t))
    base = attention(q, k, v, pos, pos)
    k2 = k.at[:, 10, :].add(7.0)
    v2 = v.at[:, 10, :].add(-3.0)
    pert = attention(q, k2, v2, pos, pos)
    np.testing.assert_allclose(base[:, :10], pert[:, :10], atol=ATOL)
    assert float(jnp.max(jnp.abs(base[:, 10:] - pert[:, 10:]))) > 1e-3


def test_sparse_mask_uses_original_positions():
    """With selected indices I, query i attends key j iff I_i >= I_j —
    verify against a brute-force construction."""
    rng = np.random.default_rng(1)
    idx = jnp.asarray([[3, 10, 11, 40]], jnp.int32)
    q, k, v = rand_qkv(rng, 1, 4, 4, 4)
    got = attention(q, k, v, idx, idx)
    # brute force with explicit mask
    s = (q @ jnp.transpose(k, (0, 2, 1))) / jnp.sqrt(4.0)
    mask = idx[0][:, None] >= idx[0][None, :]
    s = jnp.where(mask[None], s, -1e30)
    want = jax.nn.softmax(s, axis=-1) @ v
    np.testing.assert_allclose(got, want, atol=ATOL)


def test_first_row_attends_only_itself():
    rng = np.random.default_rng(2)
    q, k, v = rand_qkv(rng, 1, 8, 8, 4)
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (1, 8))
    got = attention(q, k, v, pos, pos)
    np.testing.assert_allclose(got[0, 0], v[0, 0], atol=ATOL)


# ---------------------------------------------------------------------------
# backward (custom vjp vs oracle autodiff)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 3),
    tq=st.sampled_from([4, 8, 32]),
    d=st.sampled_from([4, 8]),
    window=st.sampled_from([0, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gradients_match_oracle(n, tq, d, window, seed):
    rng = np.random.default_rng(seed)
    q, k, v = rand_qkv(rng, n, tq, tq, d)
    pos = jnp.broadcast_to(jnp.arange(tq, dtype=jnp.int32), (n, tq))
    w = jnp.asarray(rng.normal(size=(n, tq, d)), jnp.float32)

    def loss_k(q, k, v):
        return jnp.sum(attention(q, k, v, pos, pos, None, window) * w)

    def loss_r(q, k, v):
        return jnp.sum(ref.ref_attention(q, k, v, pos, pos, None, window) * w)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gk, gr, "qkv"):
        np.testing.assert_allclose(a, b, atol=5e-5, err_msg=f"grad {name}")


def test_gradients_sparse_positions():
    rng = np.random.default_rng(3)
    n, ksel, d = 2, 8, 8
    q, k, v = rand_qkv(rng, n, ksel, ksel, d)
    idx = sparse_positions(rng, n, ksel, 64)

    def loss_k(q, k, v):
        return jnp.sum(attention(q, k, v, idx, idx) ** 2)

    def loss_r(q, k, v):
        return jnp.sum(ref.ref_attention(q, k, v, idx, idx) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, atol=5e-5)


# ---------------------------------------------------------------------------
# rope
# ---------------------------------------------------------------------------


def test_rope_preserves_norm():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(2, 16, 8)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, 1000, size=(2, 16)), jnp.int32)
    y = ref.ref_rope(x, pos)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), atol=1e-4
    )


def test_rope_relative_property():
    """RoPE inner products depend only on relative positions: shifting all
    positions by a constant leaves q.k scores unchanged."""
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 4, 8)), jnp.float32)
    pos = jnp.asarray([[0, 3, 7, 12]], jnp.int32)
    s1 = jnp.einsum(
        "ntd,nsd->nts", ref.ref_rope(q, pos), ref.ref_rope(k, pos)
    )
    s2 = jnp.einsum(
        "ntd,nsd->nts", ref.ref_rope(q, pos + 55), ref.ref_rope(k, pos + 55)
    )
    np.testing.assert_allclose(s1, s2, atol=1e-3)


def test_rope_identity_at_zero():
    x = jnp.ones((1, 1, 8), jnp.float32)
    y = ref.ref_rope(x, jnp.zeros((1, 1), jnp.int32))
    np.testing.assert_allclose(y, x, atol=1e-6)


# ---------------------------------------------------------------------------
# oracle self-checks
# ---------------------------------------------------------------------------


def test_ref_attention_rows_are_convex_combinations():
    rng = np.random.default_rng(6)
    q, k, v = rand_qkv(rng, 1, 8, 8, 4)
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (1, 8))
    out = ref.ref_attention(q, k, v, pos, pos)
    lo = jnp.min(v, axis=1, keepdims=True)
    hi = jnp.max(v, axis=1, keepdims=True)
    assert bool(jnp.all(out >= lo - 1e-5) and jnp.all(out <= hi + 1e-5))


def test_lse_consistency():
    rng = np.random.default_rng(7)
    q, k, v = rand_qkv(rng, 2, 8, 8, 4)
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
    o1, lse = ref.ref_attention_lse(q, k, v, pos, pos)
    o2 = ref.ref_attention(q, k, v, pos, pos)
    np.testing.assert_allclose(o1, o2, atol=1e-5)
    assert lse.shape == (2, 8)
    assert bool(jnp.all(jnp.isfinite(lse)))
