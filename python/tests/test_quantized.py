"""Quantized paged KV-cache tests: the qpaged-vs-paged differential gate.

The quantized programs (compile/decode.py §quantized) store KV payload
pages as i8 with one f32 scale per (page, head). They cannot be
bit-identical to the f32 paged twin at the logit level — the contract
is instead:

- numerics: per-element round-trip error is bounded by scale/2 =
  page absmax / 254; degenerate pages (all-zero, single-token,
  sentinel-initialized) survive quantise→dequant exactly;
- behaviour: metadata (positions, priorities) is exact, so greedy
  teacher-forced token streams match the f32 paged twin bit-for-bit at
  micro scale (small logit perturbation never flips the argmax here —
  asserted, with the max deviation recorded);
- safety: the PAGE_SENTINEL isolation story survives the quantise
  epilogue — unbacked writes drop both payload and scale, unbacked
  reads dequantise to the empty page.

Schema tests mirror test_paged.py: the manifest ``pages`` section grows
``dtype`` + ``scale_leaf`` columns and every i8 payload leaf carries an
f32 ``<leaf>_scale`` sibling shaped [pool_pages, n].
"""

import jax
import jax.numpy as jnp
import jax.tree_util as jtu
import numpy as np
import pytest

from compile import decode as dec
from compile.model import ModelConfig, init_params

jax.config.update("jax_platform_name", "cpu")

B = 2
CAP = 32


def make_cfg(**kw):
    base = dict(
        vocab=48, d_model=16, d_head=8, d_ff=32, n_layers=2, seq_len=16,
        n_dense=2, window=0, n_sparse=0, sparse_kind="none", k_sel=0,
        use_kernel=False,
    )
    base.update(kw)
    return ModelConfig(**base)


CFGS = {
    "dense": make_cfg(),
    "local": make_cfg(window=4),
    "mosa": make_cfg(n_dense=1, n_sparse=2, sparse_kind="mosa", k_sel=4),
    "fixed": make_cfg(n_dense=1, n_sparse=2, sparse_kind="fixed", k_sel=4),
    "routing": make_cfg(n_dense=1, n_sparse=2, sparse_kind="routing", k_sel=4),
}


def setup(cfg, seed=0):
    params, state = init_params(jax.random.PRNGKey(seed), cfg)
    tokens = jax.random.randint(
        jax.random.PRNGKey(seed + 1), (B, cfg.seq_len), 0, cfg.vocab
    )
    return params, state, tokens.astype(jnp.int32)


# ---------------------------------------------------------------------------
# quantisation numerics
# ---------------------------------------------------------------------------


def test_roundtrip_error_bounded_by_page_absmax():
    """Seeded property sweep: |dequant(quant(x)) - x| <= absmax/254 per
    element, absmax taken over that (page, head) block — across scales
    spanning 12 orders of magnitude and several distributions."""
    rng = np.random.default_rng(42)
    for trial in range(20):
        scale = 10.0 ** rng.uniform(-6, 6)
        shape = (int(rng.integers(1, 9)), int(rng.integers(1, 5)), 4, 8)
        if trial % 3 == 0:
            pages = rng.normal(0, scale, size=shape)
        elif trial % 3 == 1:
            pages = rng.uniform(-scale, scale, size=shape)
        else:  # heavy-tailed: one dominant element per page
            pages = rng.normal(0, scale, size=shape)
            pages[:, :, 0, 0] *= 100.0
        pages = jnp.asarray(pages.astype(np.float32))
        q, s = dec.quantise_pages(pages)
        assert q.dtype == jnp.int8
        back = np.asarray(dec.dequantise_pages(q, s))
        absmax = np.asarray(jnp.max(jnp.abs(pages), axis=(2, 3)))
        bound = absmax[:, :, None, None] / 254.0
        err = np.abs(back - np.asarray(pages))
        # tiny epsilon: the bound itself is computed in f32
        assert (err <= bound + 1e-6 * absmax[:, :, None, None] + 1e-30).all(), trial


def test_degenerate_pages_survive_roundtrip_exactly():
    """All-zero pages, single-token pages, and the init images (zero
    payload under zero scale) quantise→dequantise exactly."""
    zero = jnp.zeros((3, 2, 4, 8), jnp.float32)
    q, s = dec.quantise_pages(zero)
    np.testing.assert_array_equal(np.asarray(s), 0.0)
    np.testing.assert_array_equal(np.asarray(dec.dequantise_pages(q, s)), 0.0)

    # single-token page: one written row, rest empty — the absmax element
    # itself always round-trips exactly (it maps to ±127)
    single = zero.at[:, :, 1, :].set(jnp.asarray(np.linspace(-3, 3, 8), jnp.float32))
    q, s = dec.quantise_pages(single)
    back = np.asarray(dec.dequantise_pages(q, s))
    np.testing.assert_array_equal(back[:, :, 0], 0.0)  # empty rows stay zero
    np.testing.assert_array_equal(back[:, :, 2:], 0.0)
    absmax = np.abs(np.asarray(single)).max(axis=(2, 3))
    assert np.abs(back[:, :, 1] - np.asarray(single)[:, :, 1]).max() <= absmax.max() / 254.0
    # the extreme element is exact
    np.testing.assert_allclose(
        np.abs(back).max(axis=(2, 3)), absmax, rtol=0, atol=0
    )


def test_init_qpools_image_matches_contiguous_init_rules():
    """Sentinel-initialized pools: payload 0 (i8), scale 0, positions
    POS_SENTINEL, priorities -1 — and a gather of the untouched pools
    reproduces the empty contiguous cache exactly."""
    cfg = CFGS["mosa"]
    spec = dec.qpage_spec(cfg, B, CAP, page_size=4)
    pools = dec.init_qpools(cfg, B, CAP, spec)
    for layer in pools["layers"]:
        for name, leaf in layer.items():
            meta = dec.leaf_meta(name)
            if meta["kind"] == "kv":
                assert leaf.dtype == jnp.int8
                np.testing.assert_array_equal(np.asarray(leaf), 0)
            elif meta["kind"] == "scale":
                assert leaf.dtype == jnp.float32
                np.testing.assert_array_equal(np.asarray(leaf), 0.0)
            elif meta["init"] == "sentinel":
                np.testing.assert_array_equal(np.asarray(leaf), dec.POS_SENTINEL)
            else:
                np.testing.assert_array_equal(np.asarray(leaf), -1.0)
    table = dec.identity_page_table(spec, B)
    gathered = dec.gather_qpools(spec, pools, table)
    for name, leaf in gathered["layers"][0].items():
        meta = dec.leaf_meta(name)
        if meta["kind"] == "kv":
            assert leaf.dtype == jnp.float32
            np.testing.assert_array_equal(np.asarray(leaf), 0.0)


# ---------------------------------------------------------------------------
# the differential contract: qpaged greedy streams == paged greedy streams
# ---------------------------------------------------------------------------


def run_twin(cfg, spec_fn, table_fn, p0=4, seed=0, steps=10):
    """Drive prefill + teacher-forced greedy decode through the f32 paged
    and quantized paged twins; returns (paged tokens, qpaged tokens,
    max |logit| deviation, per-step logits)."""
    params, state, tokens = setup(cfg, seed)
    spec = dec.page_spec(cfg, B, CAP, **spec_fn)
    qspec = dec.qpage_spec(cfg, B, CAP, **spec_fn)
    table = table_fn(spec)
    prefill_p = dec.make_prefill_paged(cfg, CAP, B, spec)
    prefill_q = dec.make_prefill_qpaged(cfg, CAP, B, qspec)
    step_p = dec.make_decode_step_paged(cfg, CAP, B, spec)
    step_q = dec.make_decode_step_qpaged(cfg, CAP, B, qspec)
    plen = jnp.full((B,), p0, jnp.int32)
    lps_p, last_p, pools_p = prefill_p(params, state, tokens, plen, table)
    lps_q, last_q, pools_q = prefill_q(params, state, tokens, plen, table)
    # prefill outputs come from the pre-quantisation forward: exact
    np.testing.assert_array_equal(np.asarray(lps_p), np.asarray(lps_q))
    np.testing.assert_array_equal(np.asarray(last_p), np.asarray(last_q))
    zero = jnp.zeros((B,), jnp.int32)
    tok_p = jnp.argmax(last_p, -1).astype(jnp.int32)
    tok_q = jnp.argmax(last_q, -1).astype(jnp.int32)
    toks_p, toks_q, dev = [np.asarray(tok_p)], [np.asarray(tok_q)], 0.0
    for t in range(p0, p0 + steps):
        pos = jnp.full((B,), t, jnp.int32)
        lp, pools_p = step_p(params, state, tok_p, pos, zero, table, pools_p)
        lq, pools_q = step_q(params, state, tok_q, pos, zero, table, pools_q)
        dev = max(dev, float(jnp.max(jnp.abs(lp - lq))))
        tok_p = jnp.argmax(lp, -1).astype(jnp.int32)
        tok_q = jnp.argmax(lq, -1).astype(jnp.int32)
        toks_p.append(np.asarray(tok_p))
        toks_q.append(np.asarray(tok_q))
    return toks_p, toks_q, dev


@pytest.mark.parametrize("name", list(CFGS))
def test_qpaged_greedy_stream_matches_paged(name):
    """>= 6 greedy steps on a fully-backed identity table: token streams
    bit-identical, max logit deviation recorded (and sane)."""
    cfg = CFGS[name]
    ps = 4 if name != "local" else 2
    toks_p, toks_q, dev = run_twin(
        cfg, dict(page_size=ps), lambda s: dec.identity_page_table(s, B), steps=10
    )
    for t, (a, b) in enumerate(zip(toks_p, toks_q)):
        np.testing.assert_array_equal(a, b, err_msg=f"{name} step {t}")
    assert np.isfinite(dev)
    print(f"\n[{name}] max |logit| deviation qpaged vs paged: {dev:.3e}")
    # the deviation must actually be a quantisation effect, not a broken
    # (e.g. all-zero) cache: bounded well below the logit scale
    assert dev < 0.1, dev


def test_qpaged_greedy_stream_matches_paged_overcommitted():
    """The acceptance scenario: an overcommitted lazy pool (pool_frac
    0.5) with one slot's dense pages left unbacked — the backed slot's
    greedy stream still matches the f32 paged twin token-for-token."""
    cfg = CFGS["mosa"]
    params, state, tokens = setup(cfg, seed=3)
    kw = dict(page_size=4, pool_frac=0.5)
    spec = dec.page_spec(cfg, B, CAP, **kw)
    qspec = dec.qpage_spec(cfg, B, CAP, **kw)
    dense = [e for e in spec["kinds"] if e["kind"] == "dense"][0]
    mosa = [e for e in spec["kinds"] if e["kind"] == "mosa"][0]
    assert dense["pool_pages"] < B * dense["pages_per_slot"]  # overcommitted
    table = np.full((B, spec["pages_per_slot"]), dec.PAGE_SENTINEL, np.int32)
    table[0, dense["row_offset"]:dense["row_offset"] + dense["pages_per_slot"]] = (
        np.arange(dense["pages_per_slot"], dtype=np.int32)
    )
    for b in range(B):
        o = mosa["row_offset"]
        table[b, o:o + mosa["pages_per_slot"]] = np.arange(
            b * mosa["pages_per_slot"], (b + 1) * mosa["pages_per_slot"], dtype=np.int32
        )
    table = jnp.asarray(table)
    step_p = dec.make_decode_step_paged(cfg, CAP, B, spec)
    step_q = dec.make_decode_step_qpaged(cfg, CAP, B, qspec)
    pools_p = dec.init_pools(cfg, B, CAP, spec)
    pools_q = dec.init_qpools(cfg, B, CAP, qspec)
    reset = jnp.asarray([1, 1], jnp.int32)
    tok_p = tok_q = tokens[:, 0]
    dev, n_steps = 0.0, 8
    for t in range(n_steps):
        pos = jnp.full((B,), t, jnp.int32)
        lp, pools_p = step_p(params, state, tok_p, pos, reset, table, pools_p)
        lq, pools_q = step_q(params, state, tok_q, pos, reset, table, pools_q)
        dev = max(dev, float(jnp.max(jnp.abs(lp[0] - lq[0]))))
        assert bool(jnp.all(jnp.isfinite(lq)))
        tok_p = jnp.argmax(lp, -1).astype(jnp.int32)
        tok_q = jnp.argmax(lq, -1).astype(jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(tok_p[0]), np.asarray(tok_q[0]), err_msg=f"step {t}"
        )
        reset = jnp.zeros((B,), jnp.int32)
    print(f"\n[overcommit] max |logit| deviation (backed slot): {dev:.3e}")
    assert dev < 0.1


def test_qpaged_permuted_table_invisible():
    """Physical page placement must be invisible to the quantized twin
    too: identity vs permuted tables give bit-identical logits (same
    pages, same scales, different physical rows)."""
    cfg = CFGS["mosa"]
    params, state, tokens = setup(cfg, seed=7)
    qspec = dec.qpage_spec(cfg, B, CAP, page_size=4)
    rng = np.random.default_rng(5)
    table_i = np.array(dec.identity_page_table(qspec, B))
    table_p = table_i.copy()
    for e in qspec["kinds"]:
        perm = rng.permutation(e["pool_pages"]).astype(np.int32)
        seg = table_p[:, e["row_offset"]:e["row_offset"] + e["pages_per_slot"]]
        table_p[:, e["row_offset"]:e["row_offset"] + e["pages_per_slot"]] = perm[seg]
    assert not np.array_equal(table_i, table_p)
    step_q = dec.make_decode_step_qpaged(cfg, CAP, B, qspec)
    outs = []
    for table in (jnp.asarray(table_i), jnp.asarray(table_p)):
        pools = dec.init_qpools(cfg, B, CAP, qspec)
        reset = jnp.asarray([1, 1], jnp.int32)
        o = []
        for t in range(6):
            pos = jnp.full((B,), t, jnp.int32)
            lq, pools = step_q(params, state, tokens[:, t], pos, reset, table, pools)
            o.append(np.asarray(lq))
            reset = jnp.zeros((B,), jnp.int32)
        outs.append(o)
    for t, (a, b) in enumerate(zip(*outs)):
        np.testing.assert_array_equal(a, b, err_msg=f"step {t}")


def test_qpaged_sample_step_matches_paged_greedy_ids():
    """decode_step_sample_qpaged with k=1 (exact greedy): sampled ids
    match the f32 paged sampling twin given the same uniforms."""
    cfg = CFGS["mosa"]
    params, state, tokens = setup(cfg, seed=9)
    spec = dec.page_spec(cfg, B, CAP, page_size=4)
    qspec = dec.qpage_spec(cfg, B, CAP, page_size=4)
    table = dec.identity_page_table(spec, B)
    samp_p = dec.make_decode_sample_paged(cfg, CAP, B, spec)
    samp_q = dec.make_decode_sample_qpaged(cfg, CAP, B, qspec)
    prefill_p = dec.make_prefill_paged(cfg, CAP, B, spec)
    prefill_q = dec.make_prefill_qpaged(cfg, CAP, B, qspec)
    plen = jnp.full((B,), 4, jnp.int32)
    _, _, pools_p = prefill_p(params, state, tokens, plen, table)
    _, _, pools_q = prefill_q(params, state, tokens, plen, table)
    rng = np.random.default_rng(11)
    zero = jnp.zeros((B,), jnp.int32)
    tok_p = tok_q = tokens[:, 4]
    for t in range(4, 11):
        pos = jnp.full((B,), t, jnp.int32)
        u = jnp.asarray(rng.random(B), jnp.float32)
        ids_p, _, _, pools_p = samp_p(
            params, state, tok_p, pos, zero, u, jnp.float32(1.0), jnp.int32(1),
            table, pools_p
        )
        ids_q, _, _, pools_q = samp_q(
            params, state, tok_q, pos, zero, u, jnp.float32(1.0), jnp.int32(1),
            table, pools_q
        )
        np.testing.assert_array_equal(np.asarray(ids_p), np.asarray(ids_q), err_msg=str(t))
        tok_p, tok_q = ids_p, ids_q


# ---------------------------------------------------------------------------
# PAGE_SENTINEL isolation under the quantise epilogue
# ---------------------------------------------------------------------------


def test_unbacked_qpaged_writes_drop_payload_and_scale():
    """A slot with unbacked dense pages drops BOTH the i8 payload write
    and the scale write; the backed slot stays exact vs a contiguous f32
    run dequantised through the same table, and unmapped pool rows keep
    their init image."""
    cfg = CFGS["mosa"]
    params, state, tokens = setup(cfg, seed=3)
    qspec = dec.qpage_spec(cfg, B, CAP, page_size=4, pool_frac=0.5)
    dense = [e for e in qspec["kinds"] if e["kind"] == "dense"][0]
    mosa = [e for e in qspec["kinds"] if e["kind"] == "mosa"][0]
    half = dense["pages_per_slot"] // 2
    table = np.full((B, qspec["pages_per_slot"]), dec.PAGE_SENTINEL, np.int32)
    # slot 0 backed on dense pages [0, half); slot 1 dense fully unbacked;
    # pool rows [half, pool_pages) mapped by nobody
    table[0, dense["row_offset"]:dense["row_offset"] + half] = np.arange(half, dtype=np.int32)
    for b in range(B):
        o = mosa["row_offset"]
        table[b, o:o + mosa["pages_per_slot"]] = np.arange(
            b * mosa["pages_per_slot"], (b + 1) * mosa["pages_per_slot"], dtype=np.int32
        )
    table = jnp.asarray(table)
    step_q = dec.make_decode_step_qpaged(cfg, CAP, B, qspec)
    pools = dec.init_qpools(cfg, B, CAP, qspec)
    reset = jnp.asarray([1, 1], jnp.int32)
    for t in range(6):
        pos = jnp.full((B,), t, jnp.int32)
        lq, pools = step_q(params, state, tokens[:, t], pos, reset, table, pools)
        assert bool(jnp.all(jnp.isfinite(lq)))
        reset = jnp.zeros((B,), jnp.int32)
    for layer in pools["layers"]:
        # unmapped dense pool rows untouched: payload 0, scale 0
        np.testing.assert_array_equal(np.asarray(layer["dense_k"][half:]), 0)
        np.testing.assert_array_equal(np.asarray(layer["dense_k_scale"][half:]), 0.0)
        np.testing.assert_array_equal(np.asarray(layer["dense_v_scale"][half:]), 0.0)
        np.testing.assert_array_equal(
            np.asarray(layer["dense_pos"][half:]), dec.POS_SENTINEL
        )
        # the backed slot DID write through (positions 0..5 live in page 0/1)
        assert np.asarray(layer["dense_pos"][0]).min() < dec.POS_SENTINEL
        assert np.asarray(layer["dense_k_scale"][:2]).max() > 0.0


def test_unbacked_qpaged_reads_dequantise_to_empty():
    """Gathering through an unbacked table entry yields the empty page:
    payload 0.0 (scale masked to 0 kills recycled garbage), positions
    POS_SENTINEL, priorities -1 — even when the pool rows hold data."""
    cfg = CFGS["mosa"]
    qspec = dec.qpage_spec(cfg, B, CAP, page_size=4)
    pools = dec.init_qpools(cfg, B, CAP, qspec)
    # poison every pool row with nonzero payload + scales + fake meta
    for layer in pools["layers"]:
        for name in list(layer):
            meta = dec.leaf_meta(name)
            if meta["kind"] == "kv":
                layer[name] = jnp.full_like(layer[name], 55)
            elif meta["kind"] == "scale":
                layer[name] = jnp.full_like(layer[name], 3.0)
            elif meta["init"] == "sentinel":
                layer[name] = jnp.zeros_like(layer[name])  # fake "position 0"
            else:
                layer[name] = jnp.full_like(layer[name], 0.9)
    table = jnp.full((B, qspec["pages_per_slot"]), dec.PAGE_SENTINEL, jnp.int32)
    gathered = dec.gather_qpools(qspec, pools, table)
    for layer in gathered["layers"]:
        for name, leaf in layer.items():
            meta = dec.leaf_meta(name)
            if meta["kind"] == "kv":
                np.testing.assert_array_equal(np.asarray(leaf), 0.0, err_msg=name)
            elif meta["init"] == "sentinel":
                np.testing.assert_array_equal(
                    np.asarray(leaf), dec.POS_SENTINEL, err_msg=name
                )
            else:
                np.testing.assert_array_equal(np.asarray(leaf), -1.0, err_msg=name)


def test_requantise_untouched_page_is_idempotent():
    """Scatter→gather→scatter of the same logical content leaves the
    pools bit-identical: dequantised values re-quantise to the same i8
    image (no drift on untouched pages across steps)."""
    cfg = CFGS["mosa"]
    qspec = dec.qpage_spec(cfg, B, CAP, page_size=4)
    table = dec.identity_page_table(qspec, B)
    rng = np.random.default_rng(17)
    caches = {"layers": []}
    for _ in range(cfg.n_layers):
        layer = {}
        for name, leaf in dec.cache_shapes(cfg, B, CAP).items():
            meta = dec.leaf_meta(name)
            if meta["kind"] == "kv":
                layer[name] = jnp.asarray(
                    rng.normal(size=leaf.shape).astype(np.float32)
                )
            elif meta["init"] == "sentinel":
                layer[name] = jnp.zeros(leaf.shape, leaf.dtype)
            else:
                layer[name] = jnp.full(leaf.shape, 0.5, leaf.dtype)
        caches["layers"].append(layer)
    pools1 = dec.scatter_qpools(
        qspec, dec.init_qpools(cfg, B, CAP, qspec), table, caches
    )
    gathered = dec.gather_qpools(qspec, pools1, table)
    pools2 = dec.scatter_qpools(qspec, pools1, table, gathered)
    for a, b in zip(jtu.tree_leaves(pools1), jtu.tree_leaves(pools2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# AOT lowering + manifest schema for the qpaged family
# ---------------------------------------------------------------------------


def test_lowered_qpaged_programs_and_manifest_schema(tmp_path):
    """lower_variant emits the quantized twins: `pages` carries dtype +
    scale_leaf, every i8 payload leaf has its f32 [pool_pages, n] scale
    sibling, donation is leaf-for-leaf identity, and the HLO reparses
    through the pinned converter."""
    from jax._src.lib import xla_client as xc

    from compile import aot, variants

    cfg = CFGS["mosa"]
    v = variants.Variant(
        name="t_qpaged", cfg=cfg, batch=B, programs=["decode"],
        group="test", base_heads=2,
        decode=variants.DecodeSpec(
            capacity=CAP, extra_batches=(1,), extra_capacities=(),
            page_size=4, pool_frac=0.5,
        ),
    )
    entry = aot.lower_variant(v, str(tmp_path))
    progs = entry["programs"]
    assert {
        "prefill_qpaged", "decode_step_qpaged", "decode_step_sample_qpaged",
        "decode_step_qpaged_b1", "decode_step_sample_qpaged_b1",
    } <= set(progs)
    n_model = entry["n_params_leaves"] + entry["n_state_leaves"]
    step = progs["decode_step_qpaged"]
    pages = step["pages"]
    assert pages["dtype"] == "i8"
    assert pages["scale_leaf"] == "_scale"
    # geometry matches the f32 twin exactly (same pools, different bytes)
    fpages = progs["decode_step_paged"]["pages"]
    assert {k: v for k, v in pages.items() if k not in ("dtype", "scale_leaf")} == fpages
    by = {e["path"]: e for e in step["cache"]}
    for path, e in by.items():
        if e["kind"] == "kv":
            assert e["dtype"] == "i8", path
            sib = by[path + "_scale"]
            assert sib["kind"] == "scale" and sib["dtype"] == "f32"
            assert sib["shape"] == e["shape"][:2], path
            assert sib["init"] == "zeros"
        elif e["kind"] == "scale":
            assert by[path[: -len("_scale")]]["dtype"] == "i8"
    # donated aliases: identity over the whole pool tree (scales included)
    n_cache = len(step["cache"])
    assert step["donated"]["aliases"] == [
        [n_model + 4 + j, 1 + j] for j in range(n_cache)
    ]
    samp = progs["decode_step_sample_qpaged"]
    assert samp["donated"]["aliases"] == [
        [n_model + 7 + j, 3 + j] for j in range(n_cache)
    ]
    assert samp["pages"] == pages
    ppf = progs["prefill_qpaged"]
    assert ppf["pages"] == pages
    assert ppf["donated"] == {"aliases": []}
    assert [e["name"] for e in ppf["extra_inputs"]] == ["tokens", "plen", "page_index"]
    # the f32 paged twin's pages section carries no quantisation columns
    assert "dtype" not in fpages and "scale_leaf" not in fpages
    for name in ["prefill_qpaged", "decode_step_qpaged", "decode_step_sample_qpaged"]:
        text = open(tmp_path / progs[name]["file"]).read()
        assert text.startswith("HloModule")
        assert xc._xla.hlo_module_from_text(text) is not None
        if name != "prefill_qpaged":
            assert aot.parse_alias_map(text) == progs[name]["donated"]["aliases"]


def test_quantized_resident_bytes_under_acceptance_ratio():
    """The BENCH headline, computed from the manifest-side geometry: on
    the bench micro specs (pool_frac 0.25), quantized resident payload
    bytes <= 0.30x the contiguous f32 worst case."""
    from compile import variants

    core = {v.name: v for v in variants.core_variants()}
    for name in ("micro_dense", "micro_mosa_r8"):
        v = core[name]
        cfg, b, cap = v.cfg, v.batch, v.decode.capacity
        qspec = dec.qpage_spec(cfg, b, cap, page_size=v.decode.page_size,
                               pool_frac=v.decode.pool_frac)
        contiguous = qpaged = 0
        for leafname, leaf in dec.cache_shapes(cfg, b, cap).items():
            if dec.leaf_meta(leafname)["kind"] != "kv":
                continue
            contiguous += int(np.prod(leaf.shape)) * 4
        for leafname, leaf in dec.qpaged_cache_shapes(cfg, b, cap, qspec).items():
            kind = dec.leaf_meta(leafname)["kind"]
            if kind == "kv":
                qpaged += int(np.prod(leaf.shape)) * 1 * cfg.n_layers
            elif kind == "scale":
                qpaged += int(np.prod(leaf.shape)) * 4 * cfg.n_layers
        contiguous *= 1  # cache_shapes is per-layer; count layers on both sides
        contiguous_total = contiguous * cfg.n_layers
        ratio = qpaged / contiguous_total
        print(f"\n[{name}] quantized/contiguous payload ratio: {ratio:.3f}")
        assert ratio <= 0.30, (name, ratio)
