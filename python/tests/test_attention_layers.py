"""L2 attention-layer semantics: MoSA routing, fixed stride, routing
clusters, hybrid composition — checked against hand-built expectations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.attention import (
    AttnSpec,
    attention_layer,
    init_attention,
    init_attention_state,
    top_k_desc,
    _mosa_heads,
    _fixed_heads,
    _scatter_heads,
)

jax.config.update("jax_platform_name", "cpu")


def spec(**kw):
    base = dict(
        d_model=32, d_head=8, seq_len=16, n_dense=1, n_sparse=2,
        sparse_kind="mosa", k_sel=4, include_first=True, use_kernel=True,
    )
    base.update(kw)
    return AttnSpec(**base)


def rand_x(b, t, h, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(b, t, h)), jnp.float32)


def test_top_k_desc_matches_lax():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 5, 32)), jnp.float32)
    v1, i1 = top_k_desc(x, 7)
    v2, i2 = jax.lax.top_k(x, 7)
    np.testing.assert_allclose(v1, v2, atol=1e-6)
    np.testing.assert_array_equal(np.sort(i1, -1), np.sort(i2, -1))


def test_mosa_include_first_forces_token0():
    s = spec()
    key = jax.random.PRNGKey(0)
    p = init_attention(key, s)["sparse"]
    x = rand_x(2, s.seq_len, s.d_model)
    # reproduce the head's selection
    r = jax.nn.sigmoid(jnp.einsum("bth,nh->bnt", x, p["wr"]))
    sel = r.at[:, :, 0].set(2.0)
    _, idx = top_k_desc(sel, s.k_sel)
    assert bool(jnp.all(jnp.any(idx == 0, axis=-1))), "token 0 must always be selected"


def test_mosa_without_include_first_is_pure_topk():
    s = spec(include_first=False)
    p = init_attention(jax.random.PRNGKey(1), s)["sparse"]
    x = rand_x(1, s.seq_len, s.d_model, seed=2)
    r = jax.nn.sigmoid(jnp.einsum("bth,nh->bnt", x, p["wr"]))
    _, idx_expected = top_k_desc(r, s.k_sel)
    # push token 0's router score very low; it must then not be selected
    # unless it's genuinely in the top-k
    assert idx_expected.shape == (1, s.n_sparse, s.k_sel)


def test_mosa_output_zero_outside_selection():
    """Tokens never selected by any head must have exactly zero output."""
    s = spec(n_dense=0, n_sparse=1, k_sel=3, include_first=False)
    p = {"sparse": init_attention(jax.random.PRNGKey(3), s)["sparse"]}
    x = rand_x(1, s.seq_len, s.d_model, seed=4)
    y = _mosa_heads(p["sparse"], x, s)
    r = jax.nn.sigmoid(jnp.einsum("bth,nh->bnt", x, p["sparse"]["wr"]))
    _, idx = top_k_desc(r, s.k_sel)
    sel = set(np.asarray(idx).ravel().tolist())
    for t in range(s.seq_len):
        row_norm = float(jnp.linalg.norm(y[0, t]))
        if t in sel:
            assert row_norm > 0
        else:
            assert row_norm == 0.0, f"unselected token {t} has nonzero output"


def test_mosa_router_gradient_flows():
    """The router Wr must receive gradient through the diag(r) scaling."""
    s = spec(n_dense=0)
    p = init_attention(jax.random.PRNGKey(4), s)
    x = rand_x(2, s.seq_len, s.d_model, seed=5)

    def loss(p):
        return jnp.sum(_mosa_heads(p["sparse"], x, s) ** 2)

    g = jax.grad(loss)(p)
    gnorm = float(jnp.linalg.norm(g["sparse"]["wr"]))
    assert gnorm > 0, "router received no gradient"


def test_fixed_heads_use_stride():
    s = spec(sparse_kind="fixed", n_dense=0, n_sparse=1, k_sel=4)  # rho=4
    p = init_attention(jax.random.PRNGKey(5), s)
    x = rand_x(1, s.seq_len, s.d_model, seed=6)
    y = _fixed_heads(p["sparse"], x, s)
    expected_idx = {0, 4, 8, 12}
    for t in range(s.seq_len):
        norm = float(jnp.linalg.norm(y[0, t]))
        if t in expected_idx:
            assert norm > 0
        else:
            assert norm == 0.0


def test_scatter_heads_accumulates_duplicates():
    y_heads = jnp.ones((1, 2, 2, 3), jnp.float32)
    idx = jnp.asarray([[[0, 1], [1, 2]]], jnp.int32)  # token 1 hit twice
    out = _scatter_heads(y_heads, idx, 4)
    np.testing.assert_allclose(out[0, 0], jnp.ones(3))
    np.testing.assert_allclose(out[0, 1], 2 * jnp.ones(3))
    np.testing.assert_allclose(out[0, 2], jnp.ones(3))
    np.testing.assert_allclose(out[0, 3], jnp.zeros(3))


@pytest.mark.parametrize("kind,n_sparse", [("mosa", 3), ("fixed", 3), ("routing", 2)])
def test_hybrid_layer_shapes_and_state(kind, n_sparse):
    s = spec(sparse_kind=kind, n_sparse=n_sparse, k_sel=4)
    key = jax.random.PRNGKey(6)
    p = init_attention(key, s)
    st = init_attention_state(key, s)
    x = rand_x(2, s.seq_len, s.d_model, seed=7)
    y, new_st = attention_layer(p, st, x, s)
    assert y.shape == x.shape
    if kind == "routing":
        assert new_st["centroids"].shape == (n_sparse, s.rho, s.d_head)
        # EMA must move the centroids
        assert float(jnp.max(jnp.abs(new_st["centroids"] - st["centroids"]))) > 0
    else:
        assert new_st == st


def test_routing_centroids_stay_normalised():
    s = spec(sparse_kind="routing", n_sparse=2, k_sel=4)
    key = jax.random.PRNGKey(8)
    p = init_attention(key, s)
    st = init_attention_state(key, s)
    x = rand_x(2, s.seq_len, s.d_model, seed=9)
    _, st2 = attention_layer(p, st, x, s)
    norms = jnp.linalg.norm(st2["centroids"], axis=-1)
    # EMA of two unit-ish vectors: stays within a sane band
    assert bool(jnp.all(norms > 0.5) and jnp.all(norms < 1.5))


def test_kernel_vs_nokernel_paths_agree():
    """config.use_kernel toggles Pallas vs oracle inside the full layer —
    outputs must agree, proving the kernel is a faithful drop-in."""
    for kind, ns in [("mosa", 2), ("fixed", 2), ("routing", 2)]:
        s1 = spec(sparse_kind=kind, n_sparse=ns, use_kernel=True)
        s2 = spec(sparse_kind=kind, n_sparse=ns, use_kernel=False)
        key = jax.random.PRNGKey(10)
        p = init_attention(key, s1)
        st = init_attention_state(key, s1)
        x = rand_x(2, s1.seq_len, s1.d_model, seed=11)
        y1, _ = attention_layer(p, st, x, s1)
        y2, _ = attention_layer(p, st, x, s2)
        np.testing.assert_allclose(y1, y2, atol=3e-5, err_msg=kind)
