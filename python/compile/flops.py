"""FLOP accounting — paper Appendix A, implemented exactly.

Mirrored in Rust (`rust/src/flops/`); both sides are unit-tested against
the paper's printed numbers (Table 4 FLOPs/pass, Table 5 head counts and
parameter counts), which this reproduction must match EXACTLY — they are
pure arithmetic, independent of hardware.

Per-head forward FLOPs (h = d_model, hp = d_head, T = seq len, k = tokens
kept per sparse head, rho = T / k):

    dense   = 8*h*hp*T + 4*hp*T^2
    mosa    = 8*h*hp*k + 4*hp*k^2 + 2*h*T + hp*k
    fixed   = 8*h*hp*k + 4*hp*k^2
    routing = 6*h*hp*T + 4*hp*k^2*rho + 2*hp*T
    local   = 8*h*hp*T + 4*hp*T*w        (window w; used in Sec 3.4 runs)

Model forward = l*H_dense*dense + l*H_sparse*sparse + 16*l*h^2*T
(feed-forward assumes d_ff = 4h as in the paper; we generalise to
4*h*d_ff*T)."""

import dataclasses


def dense_head_flops(h, hp, t):
    return 8 * h * hp * t + 4 * hp * t * t


def mosa_head_flops(h, hp, t, k):
    return 8 * h * hp * k + 4 * hp * k * k + 2 * h * t + hp * k


def fixed_head_flops(h, hp, k):
    return 8 * h * hp * k + 4 * hp * k * k


def routing_head_flops(h, hp, t, k):
    rho = t // k
    return 6 * h * hp * t + 4 * hp * k * k * rho + 2 * hp * t


def local_head_flops(h, hp, t, w):
    return 8 * h * hp * t + 4 * hp * t * w


def sparse_head_flops(kind, h, hp, t, k, w=0):
    if kind == "mosa":
        return mosa_head_flops(h, hp, t, k)
    if kind == "fixed":
        return fixed_head_flops(h, hp, k)
    if kind == "routing":
        return routing_head_flops(h, hp, t, k)
    if kind == "local":
        return local_head_flops(h, hp, t, w)
    raise ValueError(kind)


def ffn_flops(h, d_ff, t):
    return 4 * h * d_ff * t


def model_forward_flops(
    layers, h, hp, d_ff, t, n_dense, n_sparse=0, sparse_kind="none", k=0, window=0
):
    per_layer = n_dense * (
        local_head_flops(h, hp, t, window) if window > 0 else dense_head_flops(h, hp, t)
    )
    if n_sparse > 0 and sparse_kind != "none":
        per_layer += n_sparse * sparse_head_flops(sparse_kind, h, hp, t, k, window)
    per_layer += ffn_flops(h, d_ff, t)
    return layers * per_layer


def solve_sparse_heads(h, hp, t, k, n_base_dense, n_keep_dense, sparse_kind, window=0):
    """IsoFLOP head solver (paper Sec 3.2): the maximum number of sparse
    heads such that (kept dense heads + sparse heads) never exceed the
    attention FLOP budget of `n_base_dense` dense heads."""
    budget = n_base_dense * dense_head_flops(h, hp, t)
    budget -= n_keep_dense * (
        local_head_flops(h, hp, t, window) if window > 0 else dense_head_flops(h, hp, t)
    )
    if budget <= 0:
        return 0
    per = sparse_head_flops(sparse_kind, h, hp, t, k, window)
    return budget // per


def head_params(kind, h, hp):
    """Trainable parameters of one attention head."""
    if kind in ("dense", "fixed", "local"):
        return 4 * h * hp
    if kind == "mosa":
        return 4 * h * hp + h  # + router Wr
    if kind == "routing":
        return 3 * h * hp  # shared Q=K projection
    raise ValueError(kind)


def model_params(layers, h, hp, d_ff, vocab, n_dense, n_sparse=0, sparse_kind="none"):
    """Total parameter count (matches paper Table 5 at paper scale)."""
    per_layer = n_dense * head_params("dense", h, hp)
    if n_sparse > 0 and sparse_kind != "none":
        per_layer += n_sparse * head_params(sparse_kind, h, hp)
    per_layer += 2 * h * d_ff + d_ff + h  # ffn
    per_layer += 4 * h  # ln1 + ln2
    return layers * per_layer + vocab * h + h * vocab + vocab + 2 * h


# Paper dense baselines (Table 4).
PAPER_SIZES = {
    "tiny": dict(layers=6, h=512, d_ff=2048, hp=64, heads=9),
    "small": dict(layers=9, h=1024, d_ff=4096, hp=64, heads=9),
    "medium": dict(layers=18, h=1024, d_ff=4096, hp=64, heads=9),
    "large": dict(layers=27, h=1280, d_ff=5120, hp=64, heads=16),
}
PAPER_T = 1024
PAPER_VOCAB = 8000
