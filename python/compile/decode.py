"""L2: autoregressive serving programs — ``prefill`` and ``decode_step``.

The training programs process a whole [B, T] window per dispatch; serving
needs the other shape: a prompt processed once (``prefill``) and then one
token per dispatch (``decode_step``) against a device-resident KV-cache.
This module lowers cache-aware variants of every head kind:

- dense heads   append one (K, V) pair per token; cache slot = position.
- local heads   keep a ring of ``window`` pairs; cache slot = pos % window.
- MoSA heads    store only the k_sel pairs of their *selected* tokens plus
                router state (selection priorities + original positions).
                A new token enters the cache iff its router score beats the
                lowest cached priority, evicting that slot. Because a token
                outside top-k(prefix_t) can never be inside top-k(prefix_{t+1}),
                this streaming rule reproduces expert-choice top-k over the
                generated prefix *exactly*; it differs from the training
                program only in that training selects over the full window
                (expert-choice routing is not causal — the standard caveat).
                With include_first the attention-sink token keeps priority
                2.0 > sigma(.), so it is never evicted, matching training.
- fixed heads   the static stride-rho grid: position p enters slot p/rho
                iff p % rho == 0 and the grid slot exists. Fully causal, so
                decode is exact w.r.t. the training program.
- routing heads store all (shared-QK, V) pairs; at decode each new token
                is assigned to its nearest centroid and attends over cached
                tokens with the same assignment (the Routing Transformer's
                own inference-time approximation of per-cluster top-k).

Cache layout (per layer; flattened in jax.tree_util canonical order and
recorded in the manifest's per-program ``cache`` section):

    dense_k/dense_v [B, n, S, d]   dense_pos [B, n, S] i32
    mosa_k/mosa_v   [B, n, K, d]   mosa_pos  [B, n, K] i32  mosa_pri [B, n, K] f32
    fixed_k/fixed_v [B, n, K, d]   fixed_pos [B, n, K] i32
    routing_qk/routing_v [B, n, C, d]  routing_pos [B, n, C] i32

``*_k`` / ``*_v`` / ``*_qk`` leaves are the KV payload — their bytes are
exactly ``kvcache::kv_bytes_total`` on the Rust side; ``*_pos`` / ``*_pri``
are bookkeeping metadata. Empty slots carry ``POS_SENTINEL`` so the
position-aware causal mask (qpos >= kpos) hides them with no extra mask
input; MoSA priorities use -1 (< sigma(.)) so empty slots fill first.

Continuous batching needs per-slot lifecycle control, so ``decode_step``
takes per-slot ``pos`` counters and a ``reset`` flag that invalidates a
slot's cache in-graph before the token is processed — admitting a new
sequence into a used slot never round-trips the cache through the host.
"""

import math

import jax
import jax.numpy as jnp

from .attention import (
    AttnSpec,
    _dense_heads,
    _fixed_heads,
    _mosa_heads,
    _routing_heads,
    top_k_desc,
)
from .kernels.ref import ref_rope
from .model import ModelConfig, _layernorm

# Empty-cache-slot position: larger than any real position, so the causal
# mask qpos >= kpos can never select an empty slot. Mirrored in Rust
# (decode::POS_SENTINEL); keep both in sync.
POS_SENTINEL = 1 << 30

# Unbacked page-table entry: far above any physical page id, so scatters
# through it drop (jax out-of-bounds scatter semantics) and gathers are
# explicitly masked. Mirrored in Rust (kvcache::paged::PAGE_SENTINEL).
PAGE_SENTINEL = 1 << 30

# Quantized-paged pools: KV payload pages are stored i8 with one f32
# scale per (page, head), held in a sibling `<leaf>_scale` meta leaf.
# Symmetric absmax/QMAX quantisation; round-to-nearest bounds the
# per-element round-trip error by scale/2 = absmax/254.
SCALE_SUFFIX = "_scale"
QUANT_DTYPE = "i8"
QMAX = 127.0


# ---------------------------------------------------------------------------
# cache layout
# ---------------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    """One layer's cache pytree as ShapeDtypeStructs (see module doc)."""
    d = cfg.d_head
    leaf = {}
    if cfg.n_dense > 0:
        n = cfg.n_dense
        s = min(cfg.window, capacity) if cfg.window > 0 else capacity
        leaf["dense_k"] = jax.ShapeDtypeStruct((batch, n, s, d), jnp.float32)
        leaf["dense_v"] = jax.ShapeDtypeStruct((batch, n, s, d), jnp.float32)
        leaf["dense_pos"] = jax.ShapeDtypeStruct((batch, n, s), jnp.int32)
    if cfg.n_sparse > 0 and cfg.sparse_kind in ("mosa", "fixed"):
        n, k = cfg.n_sparse, cfg.k_sel
        pre = cfg.sparse_kind
        leaf[f"{pre}_k"] = jax.ShapeDtypeStruct((batch, n, k, d), jnp.float32)
        leaf[f"{pre}_v"] = jax.ShapeDtypeStruct((batch, n, k, d), jnp.float32)
        leaf[f"{pre}_pos"] = jax.ShapeDtypeStruct((batch, n, k), jnp.int32)
        if pre == "mosa":
            leaf["mosa_pri"] = jax.ShapeDtypeStruct((batch, n, k), jnp.float32)
    if cfg.n_sparse > 0 and cfg.sparse_kind == "routing":
        n = cfg.n_sparse
        leaf["routing_qk"] = jax.ShapeDtypeStruct((batch, n, capacity, d), jnp.float32)
        leaf["routing_v"] = jax.ShapeDtypeStruct((batch, n, capacity, d), jnp.float32)
        leaf["routing_pos"] = jax.ShapeDtypeStruct((batch, n, capacity), jnp.int32)
    return leaf


def cache_struct(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    return {"layers": [cache_shapes(cfg, batch, capacity) for _ in range(cfg.n_layers)]}


def leaf_meta(name: str) -> dict:
    """(kind, init) classification of a cache leaf by its name."""
    if name.endswith("_pos"):
        return {"kind": "meta", "init": "sentinel"}
    if name.endswith("_pri"):
        return {"kind": "meta", "init": "neg"}
    if name.endswith(SCALE_SUFFIX):
        return {"kind": "scale", "init": "zeros"}
    return {"kind": "kv", "init": "zeros"}


# ---------------------------------------------------------------------------
# prefill: whole-prompt forward + cache extraction
# ---------------------------------------------------------------------------


def _pad_slots(x, target, fill=0.0):
    """Pad cache axis 2 of [B, n, S0, ...] up to `target` slots."""
    s0 = x.shape[2]
    if s0 == target:
        return x
    pad = [(0, 0)] * x.ndim
    pad[2] = (0, target - s0)
    return jnp.pad(x, pad, constant_values=fill)


def _prefill_attention(p, lst, x, spec: AttnSpec, valid, plen, capacity):
    """Training-path attention with cache extraction.

    x [B,P,h]; valid [B,P] bool (position < plen); returns (y, cache dict).
    The y path calls the *training* head functions, so prefill logits match
    the score program bit-for-bit (MoSA's selection mask is the identity
    whenever plen == P).
    """
    b, t, _ = x.shape
    y = jnp.zeros_like(x)
    cache = {}
    pos_t = jnp.arange(t, dtype=jnp.int32)
    if spec.n_dense > 0:
        yd, c = _dense_heads(p["dense"], x, spec, return_cache=True)
        y = y + yd
        pos = jnp.where(valid, pos_t[None, :], POS_SENTINEL)  # [B,P]
        pos = jnp.broadcast_to(pos[:, None, :], (b, spec.n_dense, t))
        if spec.window > 0:
            w = spec.window
            s = jnp.arange(w, dtype=jnp.int32)
            # latest position congruent to s (mod w) below plen, per batch
            j = s[None, :] + w * ((plen[:, None] - 1 - s[None, :]) // w)  # [B,w]
            ok = s[None, :] < plen[:, None]
            jc = jnp.clip(j, 0, t - 1)[:, None, :]  # [B,1,w]
            take = lambda z: jnp.take_along_axis(z, jc[..., None], axis=2)
            cache["dense_k"] = take(c["k"])
            cache["dense_v"] = take(c["v"])
            ring_pos = jnp.where(ok, j, POS_SENTINEL)
            cache["dense_pos"] = jnp.broadcast_to(ring_pos[:, None, :], (b, spec.n_dense, w))
        else:
            cache["dense_k"] = _pad_slots(c["k"], capacity)
            cache["dense_v"] = _pad_slots(c["v"], capacity)
            cache["dense_pos"] = _pad_slots(pos, capacity, POS_SENTINEL)
    if spec.n_sparse > 0 and spec.sparse_kind == "mosa":
        ym, c = _mosa_heads(p["sparse"], x, spec, sel_mask=valid, return_cache=True)
        y = y + ym
        ok = c["pri"] >= 0.0  # masked prompt slots carry priority -1
        cache["mosa_k"] = c["k"]
        cache["mosa_v"] = c["v"]
        cache["mosa_pos"] = jnp.where(ok, c["idx"], POS_SENTINEL)
        cache["mosa_pri"] = c["pri"]
    if spec.n_sparse > 0 and spec.sparse_kind == "fixed":
        yf, c = _fixed_heads(p["sparse"], x, spec, return_cache=True)
        y = y + yf
        ok = c["idx"] < plen[:, None, None]
        cache["fixed_k"] = c["k"]
        cache["fixed_v"] = c["v"]
        cache["fixed_pos"] = jnp.where(ok, c["idx"], POS_SENTINEL)
    if spec.n_sparse > 0 and spec.sparse_kind == "routing":
        yr, _, c = _routing_heads(p["sparse"], x, lst, spec, return_cache=True)
        y = y + yr
        pos = jnp.where(valid, pos_t[None, :], POS_SENTINEL)
        pos = jnp.broadcast_to(pos[:, None, :], (b, spec.n_sparse, t))
        cache["routing_qk"] = _pad_slots(c["kq"], capacity)
        cache["routing_v"] = _pad_slots(c["v"], capacity)
        cache["routing_pos"] = _pad_slots(pos, capacity, POS_SENTINEL)
    return y, cache


def make_prefill(cfg: ModelConfig, capacity: int, batch: int):
    """(params, state, tokens [B,P] i32, plen [B] i32) ->
    (logprobs [B,P-1], last_logits [B,vocab], caches).

    P = cfg.seq_len. ``logprobs`` follows the score program's convention
    (log p(tokens[:,i+1] | forward) from the P-token forward), so with
    plen == P it equals ``score``'s first P-1 columns exactly. Positions
    >= plen produce garbage logits (masked out of every cache) — callers
    read only the valid prefix. plen must be >= 1 per sequence.
    """
    spec = cfg.attn_spec()
    p_len = cfg.seq_len

    def prefill(params, state, tokens, plen):
        b = tokens.shape[0]
        valid = jnp.arange(p_len, dtype=jnp.int32)[None, :] < plen[:, None]
        x = params["emb"][tokens]
        caches = []
        for lp, lst in zip(params["layers"], state["layers"]):
            a, cache = _prefill_attention(
                lp["attn"], lst, _layernorm(lp["ln1"], x), spec, valid, plen, capacity
            )
            x = x + a
            hdn = _layernorm(lp["ln2"], x)
            hdn = jax.nn.gelu(hdn @ lp["ffn"]["w1"] + lp["ffn"]["b1"])
            x = x + hdn @ lp["ffn"]["w2"] + lp["ffn"]["b2"]
            caches.append(cache)
        x = _layernorm(params["lnf"], x)
        logits = x @ params["out"] + params["out_b"]  # [B,P,V]
        lp_all = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        tgt = tokens[:, 1:]
        logprobs = jnp.take_along_axis(lp_all, tgt[..., None], axis=-1)[..., 0]
        last = jnp.clip(plen - 1, 0, p_len - 1)
        last_logits = jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0]
        return logprobs, last_logits, {"layers": caches}

    return prefill


# ---------------------------------------------------------------------------
# decode_step: one token per sequence against the cache
# ---------------------------------------------------------------------------


def _rope1(x, pos, theta):
    """x [B,n,d], pos [B] -> roped [B,n,d] at each sequence's position."""
    b, n, _ = x.shape
    p = jnp.broadcast_to(pos[:, None, None], (b, n, 1))
    return ref_rope(x[:, :, None, :], p, theta)[:, :, 0]


def _att1(spec: AttnSpec, q, ck, cv, pos, cpos, window):
    """Single-query attention: q [B,n,d] over cache [B,n,S,d] -> [B,n,d]."""
    b, n, d = q.shape
    s = ck.shape[2]
    qpos = jnp.broadcast_to(pos[:, None, None], (b, n, 1))
    return spec.att()(
        q.reshape(b * n, 1, d),
        ck.reshape(b * n, s, d),
        cv.reshape(b * n, s, d),
        qpos.reshape(b * n, 1),
        cpos.reshape(b * n, s),
        None,
        window,
    ).reshape(b, n, d)


def _write_slot(cache_k, cache_v, cache_pos, slot, write, k, v, pos):
    """Overwrite slot [B,n] (where `write` [B,n]) with the new (k, v, pos).

    Slot values outside [0, S) never match the iota, so they drop the write
    — used both for capacity overflow and for idle batch slots.
    """
    s = cache_k.shape[2]
    hit = jnp.arange(s, dtype=jnp.int32)[None, None, :] == slot[:, :, None]  # [B,n,S]
    hit = jnp.logical_and(hit, write[:, :, None])
    ck = jnp.where(hit[..., None], k[:, :, None, :], cache_k)
    cv = jnp.where(hit[..., None], v[:, :, None, :], cache_v)
    cpos = jnp.where(hit, pos[:, None, None], cache_pos)
    return ck, cv, cpos, hit


def _step_dense(p, x, cache, pos, spec: AttnSpec):
    b, _ = x.shape
    n = spec.n_dense
    q = jnp.einsum("bh,nhd->bnd", x, p["wq"])
    k = jnp.einsum("bh,nhd->bnd", x, p["wk"])
    v = jnp.einsum("bh,nhd->bnd", x, p["wv"])
    q = _rope1(q, pos, spec.rope_theta)
    k = _rope1(k, pos, spec.rope_theta)
    s = cache["dense_k"].shape[2]
    slot = jnp.mod(pos, s) if spec.window > 0 else pos  # ring vs append
    slot = jnp.broadcast_to(slot[:, None], (b, n))
    on = jnp.ones((b, n), bool)
    ck, cv, cpos, _ = _write_slot(
        cache["dense_k"], cache["dense_v"], cache["dense_pos"], slot, on, k, v, pos
    )
    att = _att1(spec, q, ck, cv, pos, cpos, spec.window)
    y = jnp.einsum("bnd,ndh->bh", att, p["wo"])
    return y, {"dense_k": ck, "dense_v": cv, "dense_pos": cpos}


def _step_mosa(p, x, cache, pos, spec: AttnSpec):
    """Streaming expert-choice: enter the cache iff the router score beats
    the lowest cached priority (see module doc); output iff entered."""
    b, _ = x.shape
    n = spec.n_sparse
    r = jax.nn.sigmoid(jnp.einsum("bh,nh->bn", x, p["wr"]))  # [B,n]
    sel = r
    if spec.include_first:
        sel = jnp.where(pos[:, None] == 0, 2.0, sel)  # attention-sink slot
    pri = cache["mosa_pri"]
    low = jnp.min(pri, axis=-1)  # [B,n]
    slot = jnp.argmin(pri, axis=-1).astype(jnp.int32)
    enter = sel > low
    q = jnp.einsum("bh,nhd->bnd", x, p["wq"])
    k = jnp.einsum("bh,nhd->bnd", x, p["wk"])
    v = jnp.einsum("bh,nhd->bnd", x, p["wv"])
    q = _rope1(q, pos, spec.rope_theta)
    k = _rope1(k, pos, spec.rope_theta)
    ck, cv, cpos, hit = _write_slot(
        cache["mosa_k"], cache["mosa_v"], cache["mosa_pos"], slot, enter, k, v, pos
    )
    cpri = jnp.where(hit, sel[:, :, None], pri)
    att = _att1(spec, q, ck, cv, pos, cpos, 0)
    att = att * jnp.where(enter, r, 0.0)[..., None]  # diag(r) path; 0 if unrouted
    y = jnp.einsum("bnd,ndh->bh", att, p["wo"])
    return y, {"mosa_k": ck, "mosa_v": cv, "mosa_pos": cpos, "mosa_pri": cpri}


def _step_fixed(p, x, cache, pos, spec: AttnSpec):
    b, _ = x.shape
    n, ksel = spec.n_sparse, spec.k_sel
    rho = spec.rho
    on_grid = jnp.logical_and(jnp.mod(pos, rho) == 0, pos < ksel * rho)  # [B]
    slot = jnp.where(on_grid, pos // rho, POS_SENTINEL)
    q = jnp.einsum("bh,nhd->bnd", x, p["wq"])
    k = jnp.einsum("bh,nhd->bnd", x, p["wk"])
    v = jnp.einsum("bh,nhd->bnd", x, p["wv"])
    q = _rope1(q, pos, spec.rope_theta)
    k = _rope1(k, pos, spec.rope_theta)
    write = jnp.broadcast_to(on_grid[:, None], (b, n))
    ck, cv, cpos, _ = _write_slot(
        cache["fixed_k"], cache["fixed_v"], cache["fixed_pos"],
        jnp.broadcast_to(slot[:, None], (b, n)), write, k, v, pos,
    )
    att = _att1(spec, q, ck, cv, pos, cpos, 0)
    att = att * write[..., None].astype(att.dtype)  # off-grid tokens: no output
    y = jnp.einsum("bnd,ndh->bh", att, p["wo"])
    return y, {"fixed_k": ck, "fixed_v": cv, "fixed_pos": cpos}


def _step_routing(p, x, lst, cache, pos, spec: AttnSpec):
    """Nearest-centroid assignment over the cached shared-QK vectors."""
    b, _ = x.shape
    n = spec.n_sparse
    mu = lst["centroids"]  # [n, rho, d]
    mun = mu / (jnp.linalg.norm(mu, axis=-1, keepdims=True) + 1e-6)
    kq = jnp.einsum("bh,nhd->bnd", x, p["wq"])  # shared projection, unroped
    v = jnp.einsum("bh,nhd->bnd", x, p["wv"])
    s = cache["routing_qk"].shape[2]
    slot = jnp.broadcast_to(pos[:, None], (b, n))
    on = jnp.ones((b, n), bool)
    cqk, cv, cpos, _ = _write_slot(
        cache["routing_qk"], cache["routing_v"], cache["routing_pos"], slot, on, kq, v, pos
    )
    kqn = kq / (jnp.linalg.norm(kq, axis=-1, keepdims=True) + 1e-6)
    own = jnp.argmax(jnp.einsum("bnd,nrd->bnr", kqn, mun), axis=-1)  # [B,n]
    cn = cqk / (jnp.linalg.norm(cqk, axis=-1, keepdims=True) + 1e-6)
    casg = jnp.argmax(jnp.einsum("bnsd,nrd->bnsr", cn, mun), axis=-1)  # [B,n,S]
    same = casg == own[:, :, None]
    # hide other-cluster entries behind the position sentinel
    cpos_m = jnp.where(same, cpos, POS_SENTINEL)
    q = _rope1(kq, pos, spec.rope_theta)
    ck = ref_rope(cqk, cpos, spec.rope_theta)  # rope cached keys at their positions
    att = _att1(spec, q, ck, cv, pos, cpos_m, 0)
    y = jnp.einsum("bnd,ndh->bh", att, p["wo"])
    return y, {"routing_qk": cqk, "routing_v": cv, "routing_pos": cpos}


def _reset_cache(cache: dict, reset):
    """In-graph slot invalidation (continuous-batching admission): where
    reset != 0, positions go to the sentinel and priorities to -1; payload
    bytes are left in place — the sentinel hides them from every mask."""
    out = {}
    hot = reset != 0  # [B]
    for name, leaf in cache.items():
        if name.endswith("_pos"):
            out[name] = jnp.where(hot[:, None, None], POS_SENTINEL, leaf)
        elif name.endswith("_pri"):
            out[name] = jnp.where(hot[:, None, None], -1.0, leaf)
        else:
            out[name] = leaf
    return out


# ---------------------------------------------------------------------------
# in-graph sampling: decode_step fused with top-k / temperature / inverse-CDF
# ---------------------------------------------------------------------------

# Static width of the in-graph top-k selection. Runtime `k` is clipped to
# [1, sample_k_max]; k = 1 is exact greedy (lax.top_k breaks ties toward
# the lower index, same as the Rust host sampler's argmax).
SAMPLE_K_MAX = 32


def sample_k_max(cfg: ModelConfig) -> int:
    return min(SAMPLE_K_MAX, cfg.vocab)


def sample_from_logits(logits, uniform, temp, k, k_max: int):
    """Fused sampling head: (logits [B,V], uniform [B] in [0,1), temp [],
    k []) -> (ids [B] i32, topk_vals [B,k_max] f32, topk_ids [B,k_max] i32).

    The draw is inverse-CDF against the f32 cumulative sum of
    exp((v - v_max)/temp) over the top-k_max logits (entries past the
    runtime k masked to 0), selecting the first slot whose cumsum reaches
    uniform * total. This is arithmetic-for-arithmetic the Rust host
    sampler (`decode::sample::sample_row_u`), so device- and host-side
    sampling agree token-for-token given the same uniforms — the parity
    the A/B harness and the artifact-gated tests pin down. Keeping the
    uniform a host input (rather than lowering a threefry graph) keeps
    the program small and the draw reproducible from either side.
    """
    # argsort-based top-k (not lax.top_k): lowers to a plain `sort` the
    # pinned HLO-text parser accepts; stable, so ties break toward the
    # lower index — same rule as the Rust host sampler
    vals, idx = top_k_desc(logits, k_max)
    temp_c = jnp.maximum(temp, 1e-4)
    kcl = jnp.clip(k, 1, k_max)
    keep = jnp.arange(k_max, dtype=jnp.int32)[None, :] < kcl
    w = jnp.where(keep, jnp.exp((vals - vals[:, :1]) / temp_c), 0.0)
    cum = jnp.cumsum(w, axis=-1)
    # total := cum[-1] (not a separate sum) so uniform < 1 guarantees a hit
    x = uniform[:, None] * cum[:, -1:]
    choice = jnp.argmax(cum >= x, axis=-1)  # first slot reaching the draw
    ids = jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0]
    return ids.astype(jnp.int32), vals, idx.astype(jnp.int32)


def make_decode_sample(cfg: ModelConfig, capacity: int, batch: int):
    """The zero-copy serving step: `make_decode_step` fused with in-graph
    sampling, so the host uploads O(B) bytes (token/pos/reset/uniform) and
    downloads O(B) bytes (sampled ids) per token instead of the full
    [B, vocab] logits. (params, state, token [B] i32, pos [B] i32,
    reset [B] i32, uniform [B] f32, temp [] f32, k [] i32, caches) ->
    (ids [B] i32, topk_vals [B,K] f32, topk_ids [B,K] i32, new caches);
    the top-k tail is a small logging/debug output the runtime fetches
    only on request."""
    step = make_decode_step(cfg, capacity, batch)
    kmx = sample_k_max(cfg)

    def sample_step(params, state, token, pos, reset, uniform, temp, k, caches):
        logits, new_caches = step(params, state, token, pos, reset, caches)
        ids, tvals, tids = sample_from_logits(logits, uniform, temp, k, kmx)
        return ids, tvals, tids, new_caches

    return sample_step


def make_decode_step(cfg: ModelConfig, capacity: int, batch: int):
    """(params, state, token [B] i32, pos [B] i32, reset [B] i32, caches)
    -> (logits [B, vocab], new caches).

    The contiguous layout: every slot owns its full-capacity cache leaves.
    The paged twin (``make_decode_step_paged``) stores the same logical
    cache in fixed-size pages of one shared pool and is bit-identical to
    this program on any fully-backed page table."""
    spec = cfg.attn_spec()

    def step(params, state, token, pos, reset, caches):
        x = params["emb"][token]  # [B,h]
        new_layers = []
        for lp, lst, lc in zip(params["layers"], state["layers"], caches["layers"]):
            lc = _reset_cache(lc, reset)
            xin = _layernorm(lp["ln1"], x)
            ap = lp["attn"]
            a = jnp.zeros_like(x)
            nc = {}
            if spec.n_dense > 0:
                yd, cd = _step_dense(ap["dense"], xin, lc, pos, spec)
                a = a + yd
                nc.update(cd)
            if spec.n_sparse > 0 and spec.sparse_kind == "mosa":
                ym, cm = _step_mosa(ap["sparse"], xin, lc, pos, spec)
                a = a + ym
                nc.update(cm)
            if spec.n_sparse > 0 and spec.sparse_kind == "fixed":
                yf, cf = _step_fixed(ap["sparse"], xin, lc, pos, spec)
                a = a + yf
                nc.update(cf)
            if spec.n_sparse > 0 and spec.sparse_kind == "routing":
                yr, cr = _step_routing(ap["sparse"], xin, lst, lc, pos, spec)
                a = a + yr
                nc.update(cr)
            x = x + a
            hdn = _layernorm(lp["ln2"], x)
            hdn = jax.nn.gelu(hdn @ lp["ffn"]["w1"] + lp["ffn"]["b1"])
            x = x + hdn @ lp["ffn"]["w2"] + lp["ffn"]["b2"]
            new_layers.append(nc)
        x = _layernorm(params["lnf"], x)
        logits = x @ params["out"] + params["out_b"]
        return logits, {"layers": new_layers}

    return step


# ---------------------------------------------------------------------------
# paged KV-cache: fixed-size pages in one pool + a host-side page table
# ---------------------------------------------------------------------------
#
# vLLM-style paging, specialised for MoSA's head mix. Each head kind's
# per-slot cache axis S is split into pages of `page_size` token slots;
# the physical storage is one pool per cache leaf, shaped
#
#     payload [pool_pages, n, page_size, d]     (was [B, n, S, d])
#     meta    [pool_pages, n, page_size]        (was [B, n, S])
#
# shared by every batch slot. A single `page_index [B, pages_per_slot]`
# i32 input maps each slot's logical pages to physical rows; the row is
# the concatenation of per-kind segments (dense / mosa / fixed / routing
# have different per-head capacities, so different page counts — the
# manifest `pages` section records each kind's row_offset). The same
# physical page id addresses that kind's pool in EVERY layer: one table
# upload serves the whole model.
#
# Overcommit is the point: the pool may hold fewer pages than
# B × pages_per_slot (lowered statically via `pool_frac`), so short
# sequences stop reserving full-capacity buffers and admission can
# oversubscribe device memory. Bounded kinds (MoSA/fixed k-slot caches,
# local rings) are never overcommitted — their pages are tiny, which is
# exactly the paper's Table 2 argument — only the capacity-sized kinds
# (dense-append, routing) page lazily with position.
#
# In-graph, the step gathers the logical view from the pools, runs the
# *same* per-head step functions as the contiguous program, and scatters
# the updated view back:
#   - gather indices are masked to 0 for unbacked entries and the
#     gathered positions/priorities forced to their empty-slot values
#     (POS_SENTINEL / -1), so garbage from recycled pages is invisible;
#   - scatter goes through the raw table, so unbacked entries
#     (PAGE_SENTINEL, out of bounds) DROP their writes — a parked slot
#     can never clobber another slot's pages.
# On a fully-backed table this is gather→identical-math→scatter, hence
# bit-identical logits and cache contents vs the contiguous program (the
# differential test harness pins this down).

# Cap on the default page size: small pages are what make overcommit
# effective at short sequence lengths.
DEFAULT_PAGE_CAP = 64


def page_kinds(cfg: ModelConfig, capacity: int):
    """Ordered (kind, per-slot cache slots, lazy) for every head kind in
    the cache layout. `lazy` kinds grow their page set with position
    (slot index == position); bounded kinds (ring / k-slot) are fully
    mapped at admission — their caches are small by construction."""
    kinds = []
    if cfg.n_dense > 0:
        if cfg.window > 0:
            kinds.append(("dense", min(cfg.window, capacity), False))
        else:
            kinds.append(("dense", capacity, True))
    if cfg.n_sparse > 0 and cfg.sparse_kind in ("mosa", "fixed"):
        kinds.append((cfg.sparse_kind, cfg.k_sel, False))
    if cfg.n_sparse > 0 and cfg.sparse_kind == "routing":
        kinds.append(("routing", capacity, True))
    return kinds


def default_page_size(cfg: ModelConfig, capacity: int) -> int:
    """Largest power-friendly page size dividing every kind's slot count,
    capped at DEFAULT_PAGE_CAP."""
    g = 0
    for _, slots, _ in page_kinds(cfg, capacity):
        g = math.gcd(g, slots)
    g = g or 1
    cap = min(g, DEFAULT_PAGE_CAP)
    # largest divisor of g that is <= cap
    for cand in range(cap, 0, -1):
        if g % cand == 0:
            return cand
    return 1


def page_spec(cfg: ModelConfig, batch: int, capacity: int,
              page_size=None, pool_frac: float = 1.0) -> dict:
    """The paging geometry of one (batch, capacity) decode family.

    Returns the dict recorded as the manifest ``pages`` section:
      page_size, pages_per_slot (total page_index row width), sentinel,
      pool_frac, and per-kind entries {kind, slots, pages_per_slot,
      row_offset, pool_pages, lazy}.

    Pool sizing: bounded kinds get the full batch × pages_per_slot (no
    overcommit — these caches are tiny); lazy kinds get
    max(pages_per_slot, ceil(batch × pages_per_slot × pool_frac)), i.e.
    at least one full-capacity sequence always fits.
    """
    if page_size is None:
        page_size = default_page_size(cfg, capacity)
    kinds = []
    off = 0
    for kind, slots, lazy in page_kinds(cfg, capacity):
        assert slots % page_size == 0, (
            f"page_size {page_size} must divide {kind} capacity {slots}"
        )
        ppk = slots // page_size
        if lazy:
            pool = max(ppk, math.ceil(batch * ppk * pool_frac))
        else:
            pool = batch * ppk
        kinds.append({
            "kind": kind, "slots": slots, "pages_per_slot": ppk,
            "row_offset": off, "pool_pages": int(pool), "lazy": lazy,
        })
        off += ppk
    return {
        "page_size": int(page_size),
        "pages_per_slot": off,
        "sentinel": PAGE_SENTINEL,
        "pool_frac": float(pool_frac),
        "kinds": kinds,
    }


def _kind_of_leaf(name: str) -> str:
    return name.split("_", 1)[0]


def _kind_entry(spec: dict, name: str) -> dict:
    kind = _kind_of_leaf(name)
    for e in spec["kinds"]:
        if e["kind"] == kind:
            return e
    raise KeyError(f"cache leaf {name} has no pages entry ({kind})")


def paged_cache_shapes(cfg: ModelConfig, batch: int, capacity: int, spec: dict) -> dict:
    """One layer's pool pytree: the paged twin of `cache_shapes` — same
    leaf names, slot axes regrouped as [pool_pages, n, page_size(, d)]."""
    ps = spec["page_size"]
    out = {}
    for name, leaf in cache_shapes(cfg, batch, capacity).items():
        e = _kind_entry(spec, name)
        n = leaf.shape[1]
        shape = (e["pool_pages"], n, ps) + tuple(leaf.shape[3:])
        out[name] = jax.ShapeDtypeStruct(shape, leaf.dtype)
    return out


def paged_cache_struct(cfg: ModelConfig, batch: int, capacity: int, spec: dict) -> dict:
    return {
        "layers": [paged_cache_shapes(cfg, batch, capacity, spec) for _ in range(cfg.n_layers)]
    }


def init_pools(cfg: ModelConfig, batch: int, capacity: int, spec: dict) -> dict:
    """Empty pools: payload zeros, positions POS_SENTINEL, priorities -1
    (same init rules as the contiguous cache leaves)."""
    def fill(name, leaf):
        meta = leaf_meta(name)
        if meta["init"] == "sentinel":
            return jnp.full(leaf.shape, POS_SENTINEL, leaf.dtype)
        if meta["init"] == "neg":
            return jnp.full(leaf.shape, -1.0, leaf.dtype)
        return jnp.zeros(leaf.shape, leaf.dtype)

    struct = paged_cache_struct(cfg, batch, capacity, spec)
    return {
        "layers": [
            {name: fill(name, leaf) for name, leaf in layer.items()}
            for layer in struct["layers"]
        ]
    }


def _gather_leaf(spec: dict, name: str, pool, page_index):
    """pool [P, n, ps(, d)] -> logical [B, n, S(, d)] via the table row
    segment of this leaf's kind, with empty-slot masking on meta leaves."""
    e = _kind_entry(spec, name)
    ps = spec["page_size"]
    ppk, off = e["pages_per_slot"], e["row_offset"]
    pi = page_index[:, off:off + ppk]  # [B, ppk]
    valid = jnp.logical_and(pi >= 0, pi < e["pool_pages"])
    idx = jnp.where(valid, pi, 0)
    view = jnp.take(pool, idx, axis=0)  # [B, ppk, n, ps(, d)]
    if pool.ndim == 4:
        b, _, n, _, d = view.shape
        view = view.transpose(0, 2, 1, 3, 4).reshape(b, n, ppk * ps, d)
        return view
    b, _, n, _ = view.shape
    view = view.transpose(0, 2, 1, 3).reshape(b, n, ppk * ps)
    # hide recycled-page garbage behind the empty-slot value: an unbacked
    # page must read as "no cached entries", exactly like a fresh slot
    vmask = jnp.repeat(valid, ps, axis=1)[:, None, :]  # [B, 1, S]
    if name.endswith("_pos"):
        return jnp.where(vmask, view, POS_SENTINEL)
    if name.endswith("_pri"):
        return jnp.where(vmask, view, -1.0)
    return view


def _scatter_leaf(spec: dict, name: str, pool, page_index, logical):
    """logical [B, n, S(, d)] -> pool, written through the raw table row
    (unbacked PAGE_SENTINEL entries are out of range: the write drops)."""
    e = _kind_entry(spec, name)
    ps = spec["page_size"]
    ppk, off = e["pages_per_slot"], e["row_offset"]
    idx = page_index[:, off:off + ppk].reshape(-1)  # [B*ppk]
    if pool.ndim == 4:
        b, n, s, d = logical.shape
        pages = logical.reshape(b, n, ppk, ps, d).transpose(0, 2, 1, 3, 4)
        pages = pages.reshape(b * ppk, n, ps, d)
    else:
        b, n, s = logical.shape
        pages = logical.reshape(b, n, ppk, ps).transpose(0, 2, 1, 3)
        pages = pages.reshape(b * ppk, n, ps)
    return pool.at[idx].set(pages, mode="drop")


def gather_pools(spec: dict, pools: dict, page_index) -> dict:
    """Pools + page table -> the logical cache pytree the contiguous step
    functions consume."""
    return {
        "layers": [
            {name: _gather_leaf(spec, name, pool, page_index) for name, pool in layer.items()}
            for layer in pools["layers"]
        ]
    }


def scatter_pools(spec: dict, pools: dict, page_index, caches: dict) -> dict:
    """Write an updated logical cache back into the pools."""
    return {
        "layers": [
            {
                name: _scatter_leaf(spec, name, pool, page_index, lc[name])
                for name, pool in layer.items()
            }
            for layer, lc in zip(pools["layers"], caches["layers"])
        ]
    }


def identity_page_table(spec: dict, batch: int):
    """The fully-backed canonical mapping: slot b's logical page j of each
    kind -> physical row b * pages_per_slot_kind + j. Only valid when no
    lazy kind is overcommitted (pool_pages == batch * pages_per_slot);
    the bit-exactness tests run on this table (or any permutation of it)."""
    import numpy as _np

    table = _np.full((batch, spec["pages_per_slot"]), PAGE_SENTINEL, _np.int32)
    for e in spec["kinds"]:
        ppk, off = e["pages_per_slot"], e["row_offset"]
        for b in range(batch):
            base = b * ppk
            assert base + ppk <= e["pool_pages"], (
                f"identity table needs pool_pages >= batch*pages_per_slot for {e['kind']}"
            )
            table[b, off:off + ppk] = _np.arange(base, base + ppk, dtype=_np.int32)
    return jnp.asarray(table)


def make_decode_step_paged(cfg: ModelConfig, capacity: int, batch: int, spec: dict):
    """(params, state, token [B] i32, pos [B] i32, reset [B] i32,
    page_index [B, pages_per_slot] i32, pools) -> (logits [B, vocab],
    new pools). Gather → contiguous step → scatter (see module section
    doc); bit-identical to `make_decode_step` on a fully-backed table."""
    step = make_decode_step(cfg, capacity, batch)

    def step_paged(params, state, token, pos, reset, page_index, pools):
        caches = gather_pools(spec, pools, page_index)
        logits, new_caches = step(params, state, token, pos, reset, caches)
        new_pools = scatter_pools(spec, pools, page_index, new_caches)
        return logits, new_pools

    return step_paged


def make_decode_sample_paged(cfg: ModelConfig, capacity: int, batch: int, spec: dict):
    """The paged twin of `make_decode_sample`: in-graph sampling over the
    paged step; host traffic per token stays O(batch) + the table upload."""
    step = make_decode_step_paged(cfg, capacity, batch, spec)
    kmx = sample_k_max(cfg)

    def sample_step(params, state, token, pos, reset, uniform, temp, k,
                    page_index, pools):
        logits, new_pools = step(params, state, token, pos, reset, page_index, pools)
        ids, tvals, tids = sample_from_logits(logits, uniform, temp, k, kmx)
        return ids, tvals, tids, new_pools

    return sample_step


def make_prefill_paged(cfg: ModelConfig, capacity: int, batch: int, spec: dict):
    """(params, state, tokens [B,P] i32, plen [B] i32,
    page_index [B, pages_per_slot] i32) -> (logprobs, last_logits, pools).

    The contiguous prefill builds the logical cache from scratch; the
    paged twin scatters it into freshly-initialised pools. Pages the
    table leaves unbacked silently drop their slots' entries — the host
    must map every page covering the prompt before dispatch (lazy kinds:
    ceil(plen / page_size) pages; bounded kinds: all of them)."""
    prefill = make_prefill(cfg, capacity, batch)

    def prefill_paged(params, state, tokens, plen, page_index):
        logprobs, last, caches = prefill(params, state, tokens, plen)
        pools = scatter_pools(
            spec, init_pools(cfg, batch, capacity, spec), page_index, caches
        )
        return logprobs, last, pools

    return prefill_paged


# ---------------------------------------------------------------------------
# quantized paged KV-cache: i8 payload pages + one f32 scale per (page, head)
# ---------------------------------------------------------------------------
#
# The paged layout gives quantisation its natural granule for free: a
# page is a small contiguous run of token slots per head, so one
# symmetric absmax scale per (page, head) pair costs 1 f32 per
# page_size × d payload elements and keeps the error local to the page.
# KV payload pools (`*_k` / `*_v` / `*_qk`) become
#
#     payload [pool_pages, n, page_size, d]  i8
#     scale   [pool_pages, n]                f32   (leaf name + "_scale")
#
# while bookkeeping metadata (`*_pos` / `*_pri`) stays exact — the
# selection machinery (causal masks, MoSA priorities, fixed grids)
# therefore behaves bit-identically to the f32 paged twin; only the
# attended K/V values are perturbed, by at most absmax/254 per element.
#
# The step is gather(dequant) → the SAME contiguous step functions →
# scatter(quantise): `scatter_qpools` computes each written page's
# absmax over its (page_size, d) payload, stores absmax/127 as the
# scale, and rounds payload/scale to the nearest i8; `gather_qpools`
# multiplies back. Re-quantising an untouched page is exact (its values
# are multiples of its scale and the absmax is preserved), so error
# does NOT accumulate across steps — only pages whose content changed
# re-quantise against a new absmax. Unbacked table entries behave as in
# the f32 paged twin: scatters drop, gathered scales are masked to 0 so
# recycled payload garbage dequantises to empty (zeros).


def qpage_spec(cfg: ModelConfig, batch: int, capacity: int,
               page_size=None, pool_frac: float = 1.0) -> dict:
    """`page_spec` plus the quantisation columns the manifest records:
    ``dtype`` (payload pool dtype) and ``scale_leaf`` (the suffix naming
    each payload leaf's f32 scale sibling)."""
    spec = page_spec(cfg, batch, capacity, page_size=page_size, pool_frac=pool_frac)
    spec["dtype"] = QUANT_DTYPE
    spec["scale_leaf"] = SCALE_SUFFIX
    return spec


def qpaged_cache_shapes(cfg: ModelConfig, batch: int, capacity: int, spec: dict) -> dict:
    """One layer's quantized pool pytree: payload leaves go i8 and gain a
    f32 [pool_pages, n] scale sibling; meta leaves match the f32 twin."""
    ps = spec["page_size"]
    out = {}
    for name, leaf in cache_shapes(cfg, batch, capacity).items():
        e = _kind_entry(spec, name)
        n = leaf.shape[1]
        shape = (e["pool_pages"], n, ps) + tuple(leaf.shape[3:])
        if leaf_meta(name)["kind"] == "kv":
            out[name] = jax.ShapeDtypeStruct(shape, jnp.int8)
            out[name + SCALE_SUFFIX] = jax.ShapeDtypeStruct((e["pool_pages"], n), jnp.float32)
        else:
            out[name] = jax.ShapeDtypeStruct(shape, leaf.dtype)
    return out


def qpaged_cache_struct(cfg: ModelConfig, batch: int, capacity: int, spec: dict) -> dict:
    return {
        "layers": [qpaged_cache_shapes(cfg, batch, capacity, spec) for _ in range(cfg.n_layers)]
    }


def init_qpools(cfg: ModelConfig, batch: int, capacity: int, spec: dict) -> dict:
    """Empty quantized pools: i8 payload zeros, f32 scale zeros (an
    all-zero page dequantises to zeros under any scale; zero is the
    canonical empty), positions POS_SENTINEL, priorities -1."""
    def fill(name, leaf):
        meta = leaf_meta(name)
        if meta["init"] == "sentinel":
            return jnp.full(leaf.shape, POS_SENTINEL, leaf.dtype)
        if meta["init"] == "neg":
            return jnp.full(leaf.shape, -1.0, leaf.dtype)
        return jnp.zeros(leaf.shape, leaf.dtype)

    struct = qpaged_cache_struct(cfg, batch, capacity, spec)
    return {
        "layers": [
            {name: fill(name, leaf) for name, leaf in layer.items()}
            for layer in struct["layers"]
        ]
    }


def quantise_pages(pages):
    """[N, n, ps, d] f32 -> (i8 payload, [N, n] f32 scales): symmetric
    per-(page, head) absmax/QMAX, round-to-nearest. All-zero pages get
    scale 0 and quantise to zeros (round-trips exactly)."""
    a = jnp.max(jnp.abs(pages), axis=(2, 3))  # [N, n]
    scale = a / QMAX
    div = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round(pages / div[:, :, None, None]), -QMAX, QMAX)
    return q.astype(jnp.int8), scale


def dequantise_pages(q, scale):
    """Inverse of `quantise_pages` up to the half-step rounding error."""
    return q.astype(jnp.float32) * scale[:, :, None, None]


def _gather_scales(spec: dict, name: str, scale_pool, page_index):
    """scale pool [P, n] -> per-slot page scales [B, ppk, n], masked to 0
    on unbacked entries so their payload garbage dequantises to empty."""
    e = _kind_entry(spec, name)
    ppk, off = e["pages_per_slot"], e["row_offset"]
    pi = page_index[:, off:off + ppk]  # [B, ppk]
    valid = jnp.logical_and(pi >= 0, pi < e["pool_pages"])
    idx = jnp.where(valid, pi, 0)
    s = jnp.take(scale_pool, idx, axis=0)  # [B, ppk, n]
    return jnp.where(valid[..., None], s, 0.0)


def gather_qpools(spec: dict, pools: dict, page_index) -> dict:
    """Quantized pools + page table -> the f32 logical cache pytree the
    contiguous step functions consume (dequant prologue)."""
    ps = spec["page_size"]
    layers = []
    for layer in pools["layers"]:
        out = {}
        for name, pool in layer.items():
            if name.endswith(SCALE_SUFFIX):
                continue
            if leaf_meta(name)["kind"] == "kv":
                raw = _gather_leaf(spec, name, pool, page_index)  # i8 [B,n,S,d]
                sc = _gather_scales(spec, name, layer[name + SCALE_SUFFIX], page_index)
                sc = jnp.repeat(sc.transpose(0, 2, 1), ps, axis=2)  # [B,n,S]
                out[name] = raw.astype(jnp.float32) * sc[..., None]
            else:
                out[name] = _gather_leaf(spec, name, pool, page_index)
        layers.append(out)
    return {"layers": layers}


def _scatter_leaf_q(spec: dict, name: str, pool, scale_pool, page_index, logical):
    """Quantise epilogue of one payload leaf: logical [B, n, S, d] f32 ->
    (i8 pool, scale pool), written through the raw table row (unbacked
    PAGE_SENTINEL entries drop both writes)."""
    e = _kind_entry(spec, name)
    ps = spec["page_size"]
    ppk, off = e["pages_per_slot"], e["row_offset"]
    idx = page_index[:, off:off + ppk].reshape(-1)  # [B*ppk]
    b, n, s, d = logical.shape
    pages = logical.reshape(b, n, ppk, ps, d).transpose(0, 2, 1, 3, 4)
    pages = pages.reshape(b * ppk, n, ps, d)
    q, scale = quantise_pages(pages)
    return (
        pool.at[idx].set(q, mode="drop"),
        scale_pool.at[idx].set(scale, mode="drop"),
    )


def scatter_qpools(spec: dict, pools: dict, page_index, caches: dict) -> dict:
    """Write an updated f32 logical cache back into the quantized pools
    (quantise epilogue on payload leaves, raw scatter on meta leaves)."""
    layers = []
    for layer, lc in zip(pools["layers"], caches["layers"]):
        out = {}
        for name, pool in layer.items():
            if name.endswith(SCALE_SUFFIX):
                continue
            if leaf_meta(name)["kind"] == "kv":
                qp, sp = _scatter_leaf_q(
                    spec, name, pool, layer[name + SCALE_SUFFIX], page_index, lc[name]
                )
                out[name] = qp
                out[name + SCALE_SUFFIX] = sp
            else:
                out[name] = _scatter_leaf(spec, name, pool, page_index, lc[name])
        layers.append(out)
    return {"layers": layers}


def make_decode_step_qpaged(cfg: ModelConfig, capacity: int, batch: int, spec: dict):
    """The quantized twin of `make_decode_step_paged`: dequant gather →
    the SAME contiguous step → quantise scatter. Same signature as the
    f32 paged step; logits deviate by at most the attention-weighted
    absmax/254 payload error (metadata and routing are exact)."""
    step = make_decode_step(cfg, capacity, batch)

    def step_qpaged(params, state, token, pos, reset, page_index, pools):
        caches = gather_qpools(spec, pools, page_index)
        logits, new_caches = step(params, state, token, pos, reset, caches)
        new_pools = scatter_qpools(spec, pools, page_index, new_caches)
        return logits, new_pools

    return step_qpaged


def make_decode_sample_qpaged(cfg: ModelConfig, capacity: int, batch: int, spec: dict):
    """In-graph sampling over the quantized paged step (the
    `decode_step_sample_qpaged*` family)."""
    step = make_decode_step_qpaged(cfg, capacity, batch, spec)
    kmx = sample_k_max(cfg)

    def sample_step(params, state, token, pos, reset, uniform, temp, k,
                    page_index, pools):
        logits, new_pools = step(params, state, token, pos, reset, page_index, pools)
        ids, tvals, tids = sample_from_logits(logits, uniform, temp, k, kmx)
        return ids, tvals, tids, new_pools

    return sample_step


def make_prefill_qpaged(cfg: ModelConfig, capacity: int, batch: int, spec: dict):
    """The quantized prefill twin: contiguous prefill, cache quantised
    into freshly-initialised i8 pools through the page table."""
    prefill = make_prefill(cfg, capacity, batch)

    def prefill_qpaged(params, state, tokens, plen, page_index):
        logprobs, last, caches = prefill(params, state, tokens, plen)
        pools = scatter_qpools(
            spec, init_qpools(cfg, batch, capacity, spec), page_index, caches
        )
        return logprobs, last, pools

    return prefill_qpaged
