"""Fallback BENCH publisher for toolchain-limited CI containers.

`verify.sh`'s perf smoke is the real publisher of `BENCH_pipeline.json` /
`BENCH_decode.json`, but it needs cargo. Some CI containers carry only
the Python artifact toolchain — historically verify.sh then published
*nothing*, the repo-root BENCH files never appeared, and the perf
trajectory stayed empty with no explanation.

This module is the honest fallback: when the Rust side cannot run, it
still proves the lowering toolchain works end-to-end — it lowers a tiny
paged+contiguous decode variant, validates the manifest invariants the
Rust runtime would check (pages geometry, donated alias identity), and
publishes BENCH stubs that say exactly *why* no wall-clock numbers exist
(`available: false`, `reason`, plus the measured lowering seconds, which
*is* a host-side perf signal: a pathological lowering regression shows
up here as a diff).

A stub never overwrites a report with real measured numbers
(`available: true`): trajectory data always wins over explanations.

Usage: cd python && python -m compile.verify_smoke \
           --pipeline-out ../BENCH_pipeline.json \
           --decode-out ../BENCH_decode.json \
           --reason "cargo not on PATH in this container"
"""

import argparse
import json
import os
import sys
import tempfile
import time


def lowering_smoke() -> dict:
    """Lower a tiny decode-capable variant (contiguous + paged programs)
    and cross-check the manifest sections; returns the timing/shape
    summary. Raises on any lowering or invariant failure."""
    import jax

    jax.config.update("jax_platform_name", "cpu")
    from compile import aot, variants
    from compile.model import ModelConfig

    cfg = ModelConfig(
        vocab=32, d_model=16, d_head=8, d_ff=32, n_layers=1, seq_len=16,
        n_dense=1, n_sparse=2, sparse_kind="mosa", k_sel=4, use_kernel=False,
    )
    v = variants.Variant(
        name="verify_smoke", cfg=cfg, batch=2, programs=["decode"],
        group="verify", base_heads=2,
        decode=variants.DecodeSpec(capacity=32, page_size=4, pool_frac=0.5),
    )
    t0 = time.monotonic()
    with tempfile.TemporaryDirectory() as out:
        entry = aot.lower_variant(v, out)
    seconds = time.monotonic() - t0
    progs = entry["programs"]
    paged = [p for p in progs if "paged" in p]
    assert "decode_step_paged" in progs and "prefill_paged" in progs, sorted(progs)
    pages = progs["decode_step_paged"]["pages"]
    off = 0
    for e in pages["kinds"]:
        assert e["row_offset"] == off and e["slots"] % pages["page_size"] == 0
        off += e["pages_per_slot"]
        assert e["pool_pages"] >= e["pages_per_slot"]
    assert off == pages["pages_per_slot"]
    n_cache = len(progs["decode_step_paged"]["cache"])
    assert len(progs["decode_step_paged"]["donated"]["aliases"]) == n_cache
    # quantized family: i8 pools + f32 scale siblings, same alias identity
    assert "decode_step_qpaged" in progs and "prefill_qpaged" in progs, sorted(progs)
    qp = progs["decode_step_qpaged"]
    qpages = qp["pages"]
    assert qpages["dtype"] == "i8" and qpages["scale_leaf"], qpages
    suffix = qpages["scale_leaf"]
    kv = {c["path"]: c for c in qp["cache"]}
    payloads = [c for c in qp["cache"] if c.get("kind") == "kv"]
    scales = [c for c in qp["cache"] if c.get("kind") == "scale"]
    assert payloads and len(scales) == len(payloads), sorted(kv)
    for c in payloads:
        assert c["dtype"] == "i8", c
        s = kv[c["path"] + suffix]
        assert s["dtype"] == "f32" and s["shape"] == c["shape"][:2], (c, s)
    assert len(qp["donated"]["aliases"]) == len(qp["cache"])
    return {
        "variant": v.name,
        "programs": len(progs),
        "paged_programs": len(paged),
        "lowering_seconds": round(seconds, 3),
        "page_size": pages["page_size"],
        "pages_per_slot": pages["pages_per_slot"],
        "quantized_scale_leaves": len(scales),
    }


def has_real_numbers(path: str) -> bool:
    """Does an existing report carry measured data a stub must not clobber?"""
    try:
        with open(path) as f:
            return bool(json.load(f).get("available"))
    except (OSError, ValueError):
        return False


def publish(path: str, report: dict) -> None:
    if has_real_numbers(path):
        print(f"verify_smoke: {path} holds real measured numbers; stub not published")
        return
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
        f.write("\n")
    print(f"verify_smoke: published {path} ({report.get('reason', 'no reason')})")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pipeline-out", required=True)
    ap.add_argument("--decode-out", required=True)
    ap.add_argument("--reason", default="rust toolchain unavailable")
    args = ap.parse_args()

    try:
        smoke = lowering_smoke()
        ok = True
        err = None
    except Exception as e:  # publish the failure, don't hide it
        smoke, ok, err = None, False, f"{type(e).__name__}: {e}"
        print(f"verify_smoke: lowering smoke FAILED: {err}", file=sys.stderr)

    base = {
        "smoke": True,
        "available": False,
        "reason": args.reason,
        "publisher": "compile.verify_smoke (python fallback)",
        "lowering_smoke": {"ok": ok, **({"error": err} if err else {}), **(smoke or {})},
    }
    publish(args.pipeline_out, {"schema": "mosa-bench-pipeline-v1", **base})
    # the faults arm (serve::chaos counters), the transport arm
    # (serve::loadgen latency percentiles), the overload arm (saturation
    # goodput/shed counters), and the prefix-sharing arm (shared-prompt
    # fan-out alloc ratios) are rust-only: stub them with the same
    # reason so the keys' trajectories are never silently empty
    publish(
        args.decode_out,
        {
            "schema": "mosa-bench-decode-v1",
            **base,
            "faults": {"available": False, "reason": args.reason},
            "transport": {"available": False, "reason": args.reason},
            "overload": {"available": False, "reason": args.reason},
            "prefix_sharing": {"available": False, "reason": args.reason},
        },
    )
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
