"""L2: training/eval step functions lowered by aot.py.

The AOT surface is four programs per model variant, all operating on a
FLAT, positional list of arrays (the Rust side never sees a pytree):

- init(seed)                      -> train_state
- train(train_state, batch, lr)   -> train_state', loss
- train_chunk(train_state, batches, lrs) -> train_state', losses   (perf)
- score(model_state, tokens)      -> per-token logprobs [B, T-1]

train_state = params ++ state ++ m ++ v ++ [t]; model_state = params ++
state. The flattening order is jax.tree_util's canonical order, recorded
in meta.json so the coordinator can name/checkpoint every slot.

Optimisation follows the paper (Sec 3 "Implementation details"): Adam,
gradient-norm clipping at 0.25, lr fed per step by the coordinator (which
owns the 4k-step linear warmup schedule).
"""

import jax
import jax.numpy as jnp

from .model import ModelConfig, init_params, loss_fn, token_logprobs

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
CLIP_NORM = 0.25


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))


def clip_by_global_norm(tree, max_norm):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (n + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), n


def init_opt(params):
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)
    t = jnp.zeros((), jnp.float32)
    return m, v, t


def adam_update(params, grads, m, v, t, lr):
    t = t + 1.0
    m = jax.tree_util.tree_map(lambda a, g: ADAM_B1 * a + (1 - ADAM_B1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda a, g: ADAM_B2 * a + (1 - ADAM_B2) * g * g, v, grads)
    bc1 = 1.0 - ADAM_B1**t
    bc2 = 1.0 - ADAM_B2**t
    params = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + ADAM_EPS),
        params,
        m,
        v,
    )
    return params, m, v, t


def make_train_step(cfg: ModelConfig):
    """(params, state, m, v, t, batch[B,T+1] i32, lr f32) ->
    (params', state', m', v', t', loss)."""

    def step(params, state, m, v, t, batch, lr):
        (loss, new_state), grads = jax.value_and_grad(
            lambda p, s: loss_fn(p, s, batch, cfg), has_aux=True
        )(params, state)
        grads, _ = clip_by_global_norm(grads, CLIP_NORM)
        params, m, v, t = adam_update(params, grads, m, v, t, lr)
        return params, new_state, m, v, t, loss

    return step


def make_train_chunk(cfg: ModelConfig, chunk: int):
    """Scan `chunk` optimisation steps inside one XLA program.

    (params, state, m, v, t, batches[S,B,T+1], lrs[S]) ->
    (..., losses[S]). One PJRT dispatch and one host round-trip per S
    steps — the L3 hot-path optimisation measured in EXPERIMENTS.md §Perf.
    """
    step = make_train_step(cfg)

    def chunk_fn(params, state, m, v, t, batches, lrs):
        def body(carry, inp):
            params, state, m, v, t = carry
            batch, lr = inp
            params, state, m, v, t, loss = step(params, state, m, v, t, batch, lr)
            return (params, state, m, v, t), loss

        (params, state, m, v, t), losses = jax.lax.scan(
            body, (params, state, m, v, t), (batches, lrs)
        )
        return params, state, m, v, t, losses

    return chunk_fn


def make_score(cfg: ModelConfig, seq_len=None):
    """(params, state, tokens[B,T] i32) -> logprobs [B, T-1].

    Serves perplexity eval (coordinator averages) and downstream
    multiple-choice scoring (coordinator masks the option span)."""

    def score(params, state, tokens):
        return token_logprobs(params, state, tokens, cfg, seq_len)

    return score


def make_init(cfg: ModelConfig):
    """(seed i32) -> full train_state pytree."""

    def init(seed):
        key = jax.random.PRNGKey(seed)
        params, state = init_params(key, cfg)
        m, v, t = init_opt(params)
        return params, state, m, v, t

    return init
