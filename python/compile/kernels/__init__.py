"""L1 Pallas kernels + jnp oracles for every attention variant."""

from . import ref  # noqa: F401
from .attention import attention, attention_nokernel  # noqa: F401
