"""L1: position-masked attention as Pallas kernels (forward + backward).

One kernel family serves every attention variant in the paper:

- dense causal heads:   qpos = kpos = arange(T)
- MoSA heads (Sec 2.2): qpos = kpos = I (expert-choice selected indices);
  the causal mask on *original* positions ``I_i >= I_j`` is computed inside
  the kernel from the position vectors.
- fixed sparse heads:   qpos = kpos = [0, rho, 2*rho, ...]
- local heads:          window > 0 adds the sliding-window constraint.
- routing heads:        qpos = kpos = per-cluster selected indices.

The kernels are written for TPU-style execution (see DESIGN.md
§Hardware-Adaptation): the grid iterates over (batch*head, query-block);
for each program instance the full K/V block of the head is resident in
VMEM. At paper scale (T = 1024, d = 64, f32) K+V occupy 512 KiB — well
inside the ~16 MiB VMEM of a TPU core, and for MoSA heads k <= 512 means
the *entire head* (Q, K, V, O) fits in < 1 MiB, which is exactly the
property that makes the expert-choice gather pay for itself: one HBM->VMEM
gather, then all attention arithmetic runs from VMEM on the MXU.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernels are lowered to plain HLO. Correctness is
asserted against the pure-jnp oracle in ``ref.py`` (python/tests/).

Autodiff: ``pallas_call`` has no automatic transpose, so ``attention`` is a
``jax.custom_vjp`` whose forward saves (q, k, v, o, lse) and whose backward
is a second Pallas kernel implementing the standard FlashAttention-style
recomputation backward pass.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30

# Query block size. Tq in this project is always a power of two >= 8; the
# block must divide Tq. 128 balances VMEM footprint against grid overhead.
_DEF_BQ = 128


def _pick_bq(tq):
    bq = min(_DEF_BQ, tq)
    while tq % bq != 0:
        bq //= 2
    return max(bq, 1)


# ---------------------------------------------------------------------------
# forward kernel
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, o_ref, lse_ref, *, scale, window):
    """One (head, query-block) program instance.

    q_ref: [bq, d] VMEM; k_ref/v_ref: [Tk, d] VMEM; qpos_ref: [bq] i32;
    kpos_ref: [Tk] i32. Writes o_ref [bq, d] and lse_ref [bq].
    """
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    qpos = qpos_ref[...]
    kpos = kpos_ref[...]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # MXU matmul
    mask = qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask = jnp.logical_and(mask, qpos[:, None] - kpos[None, :] < window)
    s = jnp.where(mask, s, NEG_INF)

    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=1, keepdims=True)
    o = jnp.dot(p, v, preferred_element_type=jnp.float32) / l
    o_ref[...] = o.astype(o_ref.dtype)
    lse_ref[...] = (m + jnp.log(l))[:, 0]


def _attention_fwd_impl(q, k, v, qpos, kpos, scale, window):
    n, tq, d = q.shape
    tk = k.shape[1]
    bq = _pick_bq(tq)
    grid = (n, tq // bq)
    o, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, tk, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, tk, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, bq), lambda i, j: (i, j)),
            pl.BlockSpec((None, tk), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, bq), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, tq, d), q.dtype),
            jax.ShapeDtypeStruct((n, tq), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, qpos, kpos)
    return o, lse


# ---------------------------------------------------------------------------
# backward kernel
# ---------------------------------------------------------------------------


def _bwd_kernel(
    q_ref, k_ref, v_ref, qpos_ref, kpos_ref, o_ref, lse_ref, do_ref,
    dq_ref, dk_ref, dv_ref, *, scale, window,
):
    """FlashAttention-style backward for one head: recompute the probability
    matrix from (q, k, lse) and form dq/dk/dv. Whole head per program
    instance — for MoSA heads Tq = Tk = k <= 512 so everything is VMEM
    resident; for dense heads at our trainable scales (T <= 2048, d <= 32)
    the score matrix is <= 16 MiB, the documented streaming threshold."""
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    qpos = qpos_ref[...]
    kpos = kpos_ref[...]
    o = o_ref[...]
    lse = lse_ref[...]
    do = do_ref[...]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    mask = qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask = jnp.logical_and(mask, qpos[:, None] - kpos[None, :] < window)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])  # [Tq, Tk] recomputed probabilities

    dv = jnp.dot(p.T, do, preferred_element_type=jnp.float32)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    delta = jnp.sum(do * o, axis=1, keepdims=True)  # rowsum(do*o) = p.dp rows
    ds = p * (dp - delta) * scale
    dq = jnp.dot(ds, k, preferred_element_type=jnp.float32)
    dk = jnp.dot(ds.T, q, preferred_element_type=jnp.float32)

    dq_ref[...] = dq.astype(dq_ref.dtype)
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _attention_bwd_impl(q, k, v, qpos, kpos, o, lse, do, scale, window):
    n, tq, d = q.shape
    tk = k.shape[1]
    grid = (n,)
    dq, dk, dv = pl.pallas_call(
        functools.partial(_bwd_kernel, scale=scale, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, tq, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, tk, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, tk, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, tq), lambda i: (i, 0)),
            pl.BlockSpec((None, tk), lambda i: (i, 0)),
            pl.BlockSpec((None, tq, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, tq), lambda i: (i, 0)),
            pl.BlockSpec((None, tq, d), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, tq, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, tk, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, tk, d), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, tq, d), q.dtype),
            jax.ShapeDtypeStruct((n, tk, d), k.dtype),
            jax.ShapeDtypeStruct((n, tk, d), v.dtype),
        ],
        interpret=True,
    )(q, k, v, qpos, kpos, o, lse, do)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# public API: differentiable position-masked attention
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def attention(q, k, v, qpos, kpos, scale=None, window=0):
    """Differentiable position-masked attention (Pallas kernels).

    q: [N, Tq, d], k/v: [N, Tk, d], qpos: [N, Tq] i32, kpos: [N, Tk] i32.
    N is the flattened batch*heads dimension. ``scale`` defaults to
    1/sqrt(d); ``window`` > 0 adds the sliding-window constraint.
    Semantics are defined by ``ref.ref_attention``.
    """
    if scale is None:
        scale = 1.0 / float(np.sqrt(q.shape[-1]))
    o, _ = _attention_fwd_impl(q, k, v, qpos, kpos, scale, window)
    return o


def _attention_vjp_fwd(q, k, v, qpos, kpos, scale, window):
    if scale is None:
        scale = 1.0 / float(np.sqrt(q.shape[-1]))
    o, lse = _attention_fwd_impl(q, k, v, qpos, kpos, scale, window)
    return o, (q, k, v, qpos, kpos, o, lse)


def _attention_vjp_bwd(scale, window, res, do):
    q, k, v, qpos, kpos, o, lse = res
    if scale is None:
        scale = 1.0 / float(np.sqrt(q.shape[-1]))
    dq, dk, dv = _attention_bwd_impl(q, k, v, qpos, kpos, o, lse, do, scale, window)
    zq = np.zeros(qpos.shape, dtype=jax.dtypes.float0)
    zk = np.zeros(kpos.shape, dtype=jax.dtypes.float0)
    return dq, dk, dv, zq, zk


attention.defvjp(_attention_vjp_fwd, _attention_vjp_bwd)


def attention_nokernel(q, k, v, qpos, kpos, scale=None, window=0):
    """Oracle-backed drop-in for `attention` (used when config.use_kernel is
    False and in A/B perf comparisons)."""
    from . import ref

    return ref.ref_attention(q, k, v, qpos, kpos, scale, window)
