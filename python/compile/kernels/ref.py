"""Pure-jnp oracles for the attention kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must match these references to numerical tolerance (see python/tests/).
They are deliberately written in the most direct way possible — full score
matrix, explicit masks — so they are easy to audit against the paper's
equations (Eq. 1 and Sec. 2.2).
"""

import jax.numpy as jnp

NEG_INF = -1e30


def position_mask(qpos, kpos, window=0):
    """Boolean mask[i, j] = True iff query at original position qpos[i] may
    attend to key at original position kpos[j].

    Causality on *original* sequence positions (paper Sec 2.2:
    ``M_ij = 0  <=>  I_i >= I_j``), optionally restricted to a sliding
    window of size ``window`` (local attention): ``qpos - kpos < window``.
    """
    m = qpos[..., :, None] >= kpos[..., None, :]
    if window > 0:
        m = jnp.logical_and(m, qpos[..., :, None] - kpos[..., None, :] < window)
    return m


def ref_attention(q, k, v, qpos, kpos, scale=None, window=0):
    """Masked attention with positions: softmax(q k^T * scale + M) v.

    q: [..., Tq, d], k, v: [..., Tk, d], qpos: [..., Tq] int32,
    kpos: [..., Tk] int32. Returns [..., Tq, d].

    Dense causal attention is the special case qpos = kpos = arange(T);
    MoSA's index-aware mask is the general case with qpos = kpos = I (the
    selected indices); local attention sets window > 0.
    """
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    s = jnp.einsum("...qd,...kd->...qk", q, k) * scale
    s = jnp.where(position_mask(qpos, kpos, window), s, NEG_INF)
    # numerically stable softmax; every query can attend to itself when the
    # qpos == kpos sets coincide, so rows are never fully masked here.
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("...qk,...kd->...qd", p, v)


def ref_attention_lse(q, k, v, qpos, kpos, scale=None, window=0):
    """Same as ref_attention but also returns the log-sum-exp per query
    (the residual the backward kernel needs)."""
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    s = jnp.einsum("...qd,...kd->...qk", q, k) * scale
    s = jnp.where(position_mask(qpos, kpos, window), s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("...qk,...kd->...qd", p / l, v)
    lse = (m + jnp.log(l))[..., 0]
    return o, lse


def ref_rope(x, pos, theta=10000.0):
    """Rotary positional embedding, aware of original token positions.

    x: [..., T, d] with d even; pos: [..., T] int32 (original sequence
    positions — for MoSA these are the *selected indices* I, per Sec 2.2
    "Positional encodings"). Rotates pairs (x[2i], x[2i+1]).
    """
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., :, None].astype(jnp.float32) * freqs  # [..., T, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)
