"""AOT driver: lower every variant's programs to HLO text + meta manifest.

HLO *text* — not ``.serialize()`` — is the interchange format: the xla
crate's xla_extension 0.5.1 rejects jax>=0.5 serialized HloModuleProto
(64-bit instruction ids); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs, per variant ``<name>``:
  artifacts/<name>.<program>.hlo.txt     one per program
  artifacts/manifest.json                global manifest the Rust side reads

The manifest records, for each variant: the model config, per-section leaf
layout (params / state / m / v / t with path names, shapes, dtypes), the
program list with their extra inputs/outputs, FLOP and parameter counts.
Array flattening is jax.tree_util's canonical order — identical between
init outputs, train inputs/outputs, and checkpoints.

Mutable-state programs (train / train_chunk / decode_step*) are lowered
with ``donate_argnums`` over their state or cache trees: XLA records an
``input_output_alias`` map in the HLO header (outputs written into the
donated input buffers — zero-copy stepping on the Rust side) and each
program's manifest entry mirrors it as a ``donated`` section, parsed
back from the artifact text and checked to be the leaf-for-leaf
identity. ``decode_step_sample*`` twins fuse in-graph sampling (top-k /
temperature / inverse-CDF over a host-supplied uniform) so serving
downloads sampled ids, not logits.

Every decode grid point additionally gets a *paged* twin
(``prefill_paged`` / ``decode_step_paged*`` / ``decode_step_sample_paged*``):
the cache lives in fixed-size pages of one shared pool per leaf,
addressed through an extra ``page_index [B, pages_per_slot] i32`` input,
with the paging geometry (page size, per-kind row segments, pool sizes,
overcommit) recorded in a per-program ``pages`` manifest section. The
contiguous programs survive unchanged as the ``--no-paged`` A/B twin.

On top of that, a *quantized* paged twin (``prefill_qpaged`` /
``decode_step_qpaged*`` / ``decode_step_sample_qpaged*``) stores KV
payload pools as i8 with one f32 scale per (page, head) in sibling
``<leaf>_scale`` leaves — dequant prologue, same step math, quantise
epilogue — cutting resident payload bytes another ~4x. Its ``pages``
section carries ``dtype`` and ``scale_leaf`` columns; the f32 paged
programs survive as the ``--no-quantized`` A/B twin.

Usage:  cd python && python -m compile.aot --set core --out ../artifacts
"""

import argparse
import dataclasses
import json
import os
import re
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import decode as dec
from . import flops, variants
from .model import ModelConfig
from .train import make_init, make_score, make_train_chunk, make_train_step


def to_hlo_text(lowered, return_tuple=False) -> str:
    """Lower to HLO text. ``return_tuple=False`` leaves the multi-output
    root as a plain tuple, which PJRT's untuple_result unpacks into one
    buffer per leaf — the property the device-resident train/decode paths
    need (each leaf can be fed back without a host round-trip). Programs
    record ``"untupled": true`` in the manifest so the Rust engine knows
    which convention an artifact was lowered with."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


# HLO-text alias entries: `{out_idx}: (in_idx, {}, may-alias)` inside the
# module header's `input_output_alias={ ... }` clause. With untupled
# lowering the output shape index is always a single tuple position.
_ALIAS_ENTRY = re.compile(r"\{\s*(\d*)\s*\}:\s*\((\d+),\s*\{\s*\},\s*(?:may|must)-alias\)")


def parse_alias_map(hlo_text: str):
    """Extract the input→output buffer alias pairs XLA recorded from
    ``donate_argnums`` — the contract the Rust runtime's donated execute
    path replays. Returns ``[[input_idx, output_idx], ...]`` sorted by
    input index (empty when the program donates nothing)."""
    header = hlo_text.split("\n", 1)[0]
    m = re.search(r"input_output_alias=\{", header)
    if m is None:
        return []
    # the clause nests one brace level ({out_idx}); scan to its close
    depth, end = 0, len(header)
    for i in range(m.end() - 1, len(header)):
        depth += {"{": 1, "}": -1}.get(header[i], 0)
        if depth == 0:
            end = i
            break
    pairs = [
        [int(e.group(2)), int(e.group(1) or 0)]
        for e in _ALIAS_ENTRY.finditer(header[m.end(): end + 1])
    ]
    return sorted(pairs)


def _check_aliases(pname, aliases, n_donated, in_offset, out_offset):
    """Donated lowerings must alias leaf-for-leaf: donated input
    ``in_offset + j`` -> output ``out_offset + j``. jax matches donated
    buffers to outputs greedily in order within each (shape, dtype)
    class, and our donated trees appear in the same order on both sides,
    so the map is exactly the identity over the donated range — anything
    else means the lowering convention drifted and the Rust runtime
    would re-feed dead buffers."""
    want = [[in_offset + j, out_offset + j] for j in range(n_donated)]
    assert aliases == want, (
        f"{pname}: alias map {aliases} != expected identity {want}"
    )


def _dt(x) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32", "int8": "i8"}.get(
        str(x.dtype), str(x.dtype)
    )


def _init_spec(section: str, name: str) -> str:
    """Host-side init rule per leaf (the Rust coordinator initialises
    parameters itself — lowering jax.random's threefry graph to HLO made
    artifact compiles ~30x slower on the pinned XLA; distributionally the
    host init is identical: N(0, 0.02), ones for LN scales, zeros for
    biases/optimizer state, row-normalised normals for centroids)."""
    if section in ("m", "v", "t"):
        return "zeros"
    if section == "state":
        return "centroid" if "centroids" in name else "zeros"
    if name.endswith(".g"):
        return "ones"
    if name.endswith(".b") or name.endswith(".b1") or name.endswith(".b2") or name.endswith("out_b"):
        return "zeros"
    return "normal:0.02"


def _path_name(path) -> str:
    name = "".join(str(p) for p in path).replace("['", ".").replace("']", "")
    return name.lstrip(".")


def _leaf_entries(tree, section):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _path_name(path)
        out.append(
            {
                "path": name,
                "shape": list(leaf.shape),
                "dtype": _dt(leaf),
                "init": _init_spec(section, name),
            }
        )
    return out


def _cache_entries(cfg: ModelConfig, batch: int, capacity: int):
    """Manifest ``cache`` section: the flat KV-cache leaf layout of one
    (batch, capacity) decode-program family, with each leaf tagged as
    payload (``kv``) or bookkeeping (``meta``) plus its init rule."""
    flat, _ = jax.tree_util.tree_flatten_with_path(dec.cache_struct(cfg, batch, capacity))
    out = []
    for path, leaf in flat:
        name = _path_name(path)
        e = {"path": name, "shape": list(leaf.shape), "dtype": _dt(leaf)}
        e.update(dec.leaf_meta(name))
        out.append(e)
    return out


def _paged_cache_entries(cfg: ModelConfig, batch: int, capacity: int, pspec: dict):
    """``cache`` section of a paged program: the same leaf names, pool
    shapes [pool_pages, n, page_size(, d)] — one shared pool per leaf,
    addressed through the ``page_index`` input (see the ``pages``
    section)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(
        dec.paged_cache_struct(cfg, batch, capacity, pspec)
    )
    out = []
    for path, leaf in flat:
        name = _path_name(path)
        e = {"path": name, "shape": list(leaf.shape), "dtype": _dt(leaf)}
        e.update(dec.leaf_meta(name))
        out.append(e)
    return out


def _qpaged_cache_entries(cfg: ModelConfig, batch: int, capacity: int, pspec: dict):
    """``cache`` section of a quantized paged program: i8 payload pools
    with their f32 ``<leaf>_scale`` siblings (kind ``scale``), meta
    leaves as in the f32 paged twin."""
    flat, _ = jax.tree_util.tree_flatten_with_path(
        dec.qpaged_cache_struct(cfg, batch, capacity, pspec)
    )
    out = []
    for path, leaf in flat:
        name = _path_name(path)
        e = {"path": name, "shape": list(leaf.shape), "dtype": _dt(leaf)}
        e.update(dec.leaf_meta(name))
        out.append(e)
    return out


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_variant(v: variants.Variant, outdir: str) -> dict:
    cfg = v.cfg
    b, t = v.batch, cfg.seq_len

    init_fn = make_init(cfg)
    shapes = jax.eval_shape(init_fn, _spec((), jnp.int32))
    params_s, state_s, m_s, v_s, t_s = shapes

    sections = {
        "params": _leaf_entries(params_s, "params"),
        "state": _leaf_entries(state_s, "state"),
        "m": _leaf_entries(m_s, "m"),
        "v": _leaf_entries(v_s, "v"),
        "t": [{"path": "t", "shape": [], "dtype": "f32", "init": "zeros"}],
    }
    n_params_leaves = len(sections["params"])
    n_state_leaves = len(sections["state"])

    progs = {}

    def emit(pname, fn, args, donate=()):
        """Lower one program; with ``donate`` (argnums), XLA records an
        input→output alias for every donated leaf, the runtime's license
        to update state/cache buffers in place instead of materialising a
        second copy per step. Returns (file name, alias pairs)."""
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{v.name}.{pname}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        return fname, parse_alias_map(text)


    # "init" is host-side (see _init_spec); an HLO init program can still
    # be emitted for cross-checking with --with-init-hlo.
    n_train_leaves = n_params_leaves * 3 + n_state_leaves + 1

    if "init_hlo" in v.programs:
        fname, _ = emit("init", init_fn, [_spec((), jnp.int32)])
        progs["init"] = {"file": fname, "extra_inputs": [
            {"name": "seed", "shape": [], "dtype": "i32"}]}

    if "train" in v.programs:
        step = make_train_step(cfg)
        # donate the whole train state (params/state/m/v/t): outputs alias
        # the input buffers, so a step updates the resident state in place
        # instead of materialising a second full copy on device
        fname, aliases = emit(
            "train", step,
            [params_s, state_s, m_s, v_s, t_s,
             _spec((b, t + 1), jnp.int32), _spec((), jnp.float32)],
            donate=(0, 1, 2, 3, 4),
        )
        _check_aliases("train", aliases, n_train_leaves, 0, 0)
        progs["train"] = {
            "file": fname,
            "extra_inputs": [
                {"name": "batch", "shape": [b, t + 1], "dtype": "i32"},
                {"name": "lr", "shape": [], "dtype": "f32"},
            ],
            "extra_outputs": [{"name": "loss", "shape": [], "dtype": "f32"}],
            "donated": {"aliases": aliases},
        }

    if "train_chunk" in v.programs:
        s = variants.CHUNK_STEPS
        chunk = make_train_chunk(cfg, s)
        fname, aliases = emit(
            "train_chunk", chunk,
            [params_s, state_s, m_s, v_s, t_s,
             _spec((s, b, t + 1), jnp.int32), _spec((s,), jnp.float32)],
            donate=(0, 1, 2, 3, 4),
        )
        _check_aliases("train_chunk", aliases, n_train_leaves, 0, 0)
        progs["train_chunk"] = {
            "file": fname,
            "chunk": s,
            "extra_inputs": [
                {"name": "batches", "shape": [s, b, t + 1], "dtype": "i32"},
                {"name": "lrs", "shape": [s], "dtype": "f32"},
            ],
            "extra_outputs": [{"name": "losses", "shape": [s], "dtype": "f32"}],
            "donated": {"aliases": aliases},
        }

    if "score" in v.programs:
        score = make_score(cfg)
        fname, _ = emit("score", lambda p, s, tok: score(p, s, tok),
                        [params_s, state_s, _spec((b, t + 1), jnp.int32)])
        progs["score"] = {
            "file": fname,
            "extra_inputs": [{"name": "tokens", "shape": [b, t + 1], "dtype": "i32"}],
            "extra_outputs": [{"name": "logprobs", "shape": [b, t], "dtype": "f32"}],
        }

    if "score_short" in v.programs:
        scfg = v.short_cfg()
        st = variants.SHORT_T
        if cfg.sparse_kind == "routing":
            # centroid count must be preserved: the trained state is an input
            assert scfg.attn_spec().rho == cfg.attn_spec().rho, v.name
        score = make_score(dataclasses.replace(scfg))
        fname, _ = emit("score_short", lambda p, s, tok: score(p, s, tok),
                        [params_s, state_s, _spec((1, st + 1), jnp.int32)])
        progs["score_short"] = {
            "file": fname,
            "seq_len": st,
            "k_sel": scfg.k_sel,
            "extra_inputs": [{"name": "tokens", "shape": [1, st + 1], "dtype": "i32"}],
            "extra_outputs": [{"name": "logprobs", "shape": [1, st], "dtype": "f32"}],
        }

    if "decode" in v.programs and v.decode is not None:
        dcap = v.decode.capacity
        assert dcap >= t, f"{v.name}: decode capacity {dcap} < prompt length {t}"
        vocab = cfg.vocab
        n_model = n_params_leaves + n_state_leaves

        def emit_step(pname, bb, cc):
            step = dec.make_decode_step(cfg, cc, bb)
            cstruct = dec.cache_struct(cfg, bb, cc)
            cache_entries = _cache_entries(cfg, bb, cc)
            # donate the cache tree (arg 5): every cache leaf aliases its
            # output slot, so the resident cache is stepped in place
            fname, aliases = emit(
                pname, step,
                [params_s, state_s, _spec((bb,), jnp.int32), _spec((bb,), jnp.int32),
                 _spec((bb,), jnp.int32), cstruct],
                donate=(5,),
            )
            _check_aliases(pname, aliases, len(cache_entries), n_model + 3, 1)
            progs[pname] = {
                "file": fname,
                "batch": bb,
                "capacity": cc,
                "extra_inputs": [
                    {"name": "token", "shape": [bb], "dtype": "i32"},
                    {"name": "pos", "shape": [bb], "dtype": "i32"},
                    {"name": "reset", "shape": [bb], "dtype": "i32"},
                ],
                "extra_outputs": [{"name": "logits", "shape": [bb, vocab], "dtype": "f32"}],
                "cache": cache_entries,
                "donated": {"aliases": aliases},
            }

        def emit_sample(pname, bb, cc):
            """decode_step fused with in-graph sampling: host traffic per
            token is O(batch) both ways (uniform up, sampled ids down)."""
            kmx = dec.sample_k_max(cfg)
            step = dec.make_decode_sample(cfg, cc, bb)
            cstruct = dec.cache_struct(cfg, bb, cc)
            cache_entries = _cache_entries(cfg, bb, cc)
            fname, aliases = emit(
                pname, step,
                [params_s, state_s, _spec((bb,), jnp.int32), _spec((bb,), jnp.int32),
                 _spec((bb,), jnp.int32), _spec((bb,), jnp.float32),
                 _spec((), jnp.float32), _spec((), jnp.int32), cstruct],
                donate=(8,),
            )
            _check_aliases(pname, aliases, len(cache_entries), n_model + 6, 3)
            progs[pname] = {
                "file": fname,
                "batch": bb,
                "capacity": cc,
                "sample_k": kmx,
                "extra_inputs": [
                    {"name": "token", "shape": [bb], "dtype": "i32"},
                    {"name": "pos", "shape": [bb], "dtype": "i32"},
                    {"name": "reset", "shape": [bb], "dtype": "i32"},
                    {"name": "uniform", "shape": [bb], "dtype": "f32"},
                    {"name": "temp", "shape": [], "dtype": "f32"},
                    {"name": "k", "shape": [], "dtype": "i32"},
                ],
                "extra_outputs": [
                    {"name": "ids", "shape": [bb], "dtype": "i32"},
                    {"name": "topk_vals", "shape": [bb, kmx], "dtype": "f32"},
                    {"name": "topk_ids", "shape": [bb, kmx], "dtype": "i32"},
                ],
                "cache": cache_entries,
                "donated": {"aliases": aliases},
            }

        def pages_of(bb, cc):
            return dec.page_spec(
                cfg, bb, cc, page_size=v.decode.page_size, pool_frac=v.decode.pool_frac
            )

        def emit_step_paged(pname, bb, cc):
            """The paged twin of `emit_step`: same computation over pooled
            pages, addressed through an extra `page_index` input (the only
            per-step host→device traffic the layout adds)."""
            pspec = pages_of(bb, cc)
            step = dec.make_decode_step_paged(cfg, cc, bb, pspec)
            pstruct = dec.paged_cache_struct(cfg, bb, cc, pspec)
            cache_entries = _paged_cache_entries(cfg, bb, cc, pspec)
            row = pspec["pages_per_slot"]
            fname, aliases = emit(
                pname, step,
                [params_s, state_s, _spec((bb,), jnp.int32), _spec((bb,), jnp.int32),
                 _spec((bb,), jnp.int32), _spec((bb, row), jnp.int32), pstruct],
                donate=(6,),
            )
            _check_aliases(pname, aliases, len(cache_entries), n_model + 4, 1)
            progs[pname] = {
                "file": fname,
                "batch": bb,
                "capacity": cc,
                "extra_inputs": [
                    {"name": "token", "shape": [bb], "dtype": "i32"},
                    {"name": "pos", "shape": [bb], "dtype": "i32"},
                    {"name": "reset", "shape": [bb], "dtype": "i32"},
                    {"name": "page_index", "shape": [bb, row], "dtype": "i32"},
                ],
                "extra_outputs": [{"name": "logits", "shape": [bb, vocab], "dtype": "f32"}],
                "cache": cache_entries,
                "pages": pspec,
                "donated": {"aliases": aliases},
            }

        def emit_sample_paged(pname, bb, cc):
            pspec = pages_of(bb, cc)
            kmx = dec.sample_k_max(cfg)
            step = dec.make_decode_sample_paged(cfg, cc, bb, pspec)
            pstruct = dec.paged_cache_struct(cfg, bb, cc, pspec)
            cache_entries = _paged_cache_entries(cfg, bb, cc, pspec)
            row = pspec["pages_per_slot"]
            fname, aliases = emit(
                pname, step,
                [params_s, state_s, _spec((bb,), jnp.int32), _spec((bb,), jnp.int32),
                 _spec((bb,), jnp.int32), _spec((bb,), jnp.float32),
                 _spec((), jnp.float32), _spec((), jnp.int32),
                 _spec((bb, row), jnp.int32), pstruct],
                donate=(9,),
            )
            _check_aliases(pname, aliases, len(cache_entries), n_model + 7, 3)
            progs[pname] = {
                "file": fname,
                "batch": bb,
                "capacity": cc,
                "sample_k": kmx,
                "extra_inputs": [
                    {"name": "token", "shape": [bb], "dtype": "i32"},
                    {"name": "pos", "shape": [bb], "dtype": "i32"},
                    {"name": "reset", "shape": [bb], "dtype": "i32"},
                    {"name": "uniform", "shape": [bb], "dtype": "f32"},
                    {"name": "temp", "shape": [], "dtype": "f32"},
                    {"name": "k", "shape": [], "dtype": "i32"},
                    {"name": "page_index", "shape": [bb, row], "dtype": "i32"},
                ],
                "extra_outputs": [
                    {"name": "ids", "shape": [bb], "dtype": "i32"},
                    {"name": "topk_vals", "shape": [bb, kmx], "dtype": "f32"},
                    {"name": "topk_ids", "shape": [bb, kmx], "dtype": "i32"},
                ],
                "cache": cache_entries,
                "pages": pspec,
                "donated": {"aliases": aliases},
            }

        def qpages_of(bb, cc):
            return dec.qpage_spec(
                cfg, bb, cc, page_size=v.decode.page_size, pool_frac=v.decode.pool_frac
            )

        def emit_step_qpaged(pname, bb, cc):
            """The quantized twin of `emit_step_paged`: i8 payload pools
            + f32 per-page scales, dequant/quantise around the SAME step;
            the `pages` section grows `dtype` and `scale_leaf` columns."""
            pspec = qpages_of(bb, cc)
            step = dec.make_decode_step_qpaged(cfg, cc, bb, pspec)
            pstruct = dec.qpaged_cache_struct(cfg, bb, cc, pspec)
            cache_entries = _qpaged_cache_entries(cfg, bb, cc, pspec)
            row = pspec["pages_per_slot"]
            fname, aliases = emit(
                pname, step,
                [params_s, state_s, _spec((bb,), jnp.int32), _spec((bb,), jnp.int32),
                 _spec((bb,), jnp.int32), _spec((bb, row), jnp.int32), pstruct],
                donate=(6,),
            )
            _check_aliases(pname, aliases, len(cache_entries), n_model + 4, 1)
            progs[pname] = {
                "file": fname,
                "batch": bb,
                "capacity": cc,
                "extra_inputs": [
                    {"name": "token", "shape": [bb], "dtype": "i32"},
                    {"name": "pos", "shape": [bb], "dtype": "i32"},
                    {"name": "reset", "shape": [bb], "dtype": "i32"},
                    {"name": "page_index", "shape": [bb, row], "dtype": "i32"},
                ],
                "extra_outputs": [{"name": "logits", "shape": [bb, vocab], "dtype": "f32"}],
                "cache": cache_entries,
                "pages": pspec,
                "donated": {"aliases": aliases},
            }

        def emit_sample_qpaged(pname, bb, cc):
            pspec = qpages_of(bb, cc)
            kmx = dec.sample_k_max(cfg)
            step = dec.make_decode_sample_qpaged(cfg, cc, bb, pspec)
            pstruct = dec.qpaged_cache_struct(cfg, bb, cc, pspec)
            cache_entries = _qpaged_cache_entries(cfg, bb, cc, pspec)
            row = pspec["pages_per_slot"]
            fname, aliases = emit(
                pname, step,
                [params_s, state_s, _spec((bb,), jnp.int32), _spec((bb,), jnp.int32),
                 _spec((bb,), jnp.int32), _spec((bb,), jnp.float32),
                 _spec((), jnp.float32), _spec((), jnp.int32),
                 _spec((bb, row), jnp.int32), pstruct],
                donate=(9,),
            )
            _check_aliases(pname, aliases, len(cache_entries), n_model + 7, 3)
            progs[pname] = {
                "file": fname,
                "batch": bb,
                "capacity": cc,
                "sample_k": kmx,
                "extra_inputs": [
                    {"name": "token", "shape": [bb], "dtype": "i32"},
                    {"name": "pos", "shape": [bb], "dtype": "i32"},
                    {"name": "reset", "shape": [bb], "dtype": "i32"},
                    {"name": "uniform", "shape": [bb], "dtype": "f32"},
                    {"name": "temp", "shape": [], "dtype": "f32"},
                    {"name": "k", "shape": [], "dtype": "i32"},
                    {"name": "page_index", "shape": [bb, row], "dtype": "i32"},
                ],
                "extra_outputs": [
                    {"name": "ids", "shape": [bb], "dtype": "i32"},
                    {"name": "topk_vals", "shape": [bb, kmx], "dtype": "f32"},
                    {"name": "topk_ids", "shape": [bb, kmx], "dtype": "i32"},
                ],
                "cache": cache_entries,
                "pages": pspec,
                "donated": {"aliases": aliases},
            }

        prefill = dec.make_prefill(cfg, dcap, b)
        # prefill builds the cache from scratch (cache leaves are outputs
        # only), so there is nothing aliasable to donate; the empty
        # `donated` section still marks the artifact donation-aware.
        fname, _ = emit(
            "prefill", prefill,
            [params_s, state_s, _spec((b, t), jnp.int32), _spec((b,), jnp.int32)],
        )
        progs["prefill"] = {
            "file": fname,
            "batch": b,
            "capacity": dcap,
            "prompt_len": t,
            "extra_inputs": [
                {"name": "tokens", "shape": [b, t], "dtype": "i32"},
                {"name": "plen", "shape": [b], "dtype": "i32"},
            ],
            "extra_outputs": [
                {"name": "logprobs", "shape": [b, t - 1], "dtype": "f32"},
                {"name": "last_logits", "shape": [b, vocab], "dtype": "f32"},
            ],
            "cache": _cache_entries(cfg, b, dcap),
            "donated": {"aliases": []},
        }
        # the paged prefill twin: same forward, cache scattered into the
        # shared pools through the page table (output-only, no donation)
        ppf_spec = pages_of(b, dcap)
        prefill_paged = dec.make_prefill_paged(cfg, dcap, b, ppf_spec)
        ppf_row = ppf_spec["pages_per_slot"]
        fname, _ = emit(
            "prefill_paged", prefill_paged,
            [params_s, state_s, _spec((b, t), jnp.int32), _spec((b,), jnp.int32),
             _spec((b, ppf_row), jnp.int32)],
        )
        progs["prefill_paged"] = {
            "file": fname,
            "batch": b,
            "capacity": dcap,
            "prompt_len": t,
            "extra_inputs": [
                {"name": "tokens", "shape": [b, t], "dtype": "i32"},
                {"name": "plen", "shape": [b], "dtype": "i32"},
                {"name": "page_index", "shape": [b, ppf_row], "dtype": "i32"},
            ],
            "extra_outputs": [
                {"name": "logprobs", "shape": [b, t - 1], "dtype": "f32"},
                {"name": "last_logits", "shape": [b, vocab], "dtype": "f32"},
            ],
            "cache": _paged_cache_entries(cfg, b, dcap, ppf_spec),
            "pages": ppf_spec,
            "donated": {"aliases": []},
        }
        # the quantized prefill twin: i8 pools + per-page scales
        qpf_spec = qpages_of(b, dcap)
        prefill_qpaged = dec.make_prefill_qpaged(cfg, dcap, b, qpf_spec)
        qpf_row = qpf_spec["pages_per_slot"]
        fname, _ = emit(
            "prefill_qpaged", prefill_qpaged,
            [params_s, state_s, _spec((b, t), jnp.int32), _spec((b,), jnp.int32),
             _spec((b, qpf_row), jnp.int32)],
        )
        progs["prefill_qpaged"] = {
            "file": fname,
            "batch": b,
            "capacity": dcap,
            "prompt_len": t,
            "extra_inputs": [
                {"name": "tokens", "shape": [b, t], "dtype": "i32"},
                {"name": "plen", "shape": [b], "dtype": "i32"},
                {"name": "page_index", "shape": [b, qpf_row], "dtype": "i32"},
            ],
            "extra_outputs": [
                {"name": "logprobs", "shape": [b, t - 1], "dtype": "f32"},
                {"name": "last_logits", "shape": [b, vocab], "dtype": "f32"},
            ],
            "cache": _qpaged_cache_entries(cfg, b, dcap, qpf_spec),
            "pages": qpf_spec,
            "donated": {"aliases": []},
        }
        emit_step("decode_step", b, dcap)
        emit_sample("decode_step_sample", b, dcap)
        emit_step_paged("decode_step_paged", b, dcap)
        emit_sample_paged("decode_step_sample_paged", b, dcap)
        emit_step_qpaged("decode_step_qpaged", b, dcap)
        emit_sample_qpaged("decode_step_sample_qpaged", b, dcap)
        for bb in v.decode.extra_batches:
            emit_step(f"decode_step_b{bb}", bb, dcap)
            emit_sample(f"decode_step_sample_b{bb}", bb, dcap)
            emit_step_paged(f"decode_step_paged_b{bb}", bb, dcap)
            emit_sample_paged(f"decode_step_sample_paged_b{bb}", bb, dcap)
            emit_step_qpaged(f"decode_step_qpaged_b{bb}", bb, dcap)
            emit_sample_qpaged(f"decode_step_sample_qpaged_b{bb}", bb, dcap)
        for cc in v.decode.extra_capacities:
            emit_step(f"decode_step_c{cc}", b, cc)
            emit_step_paged(f"decode_step_paged_c{cc}", b, cc)
            emit_step_qpaged(f"decode_step_qpaged_c{cc}", b, cc)

    for prog in progs.values():
        # everything in this generation is lowered with return_tuple=False
        # (see to_hlo_text); the flag tells the Rust engine which output
        # convention to expect, keeping old tuple-style artifacts loadable.
        prog["untupled"] = True

    fwd_flops = flops.model_forward_flops(
        cfg.n_layers, cfg.d_model, cfg.d_head, cfg.d_ff, cfg.seq_len,
        cfg.n_dense, cfg.n_sparse, cfg.sparse_kind, cfg.k_sel, cfg.window,
    )
    n_params = sum(
        int(jnp.prod(jnp.asarray(e["shape"]))) if e["shape"] else 1
        for e in sections["params"]
    )
    return {
        "name": v.name,
        "group": v.group,
        "batch": b,
        "base_heads": v.base_heads,
        "config": dataclasses.asdict(cfg),
        "rho": cfg.attn_spec().rho if cfg.n_sparse > 0 else 1,
        "flops_fwd": int(fwd_flops),
        "n_params": int(n_params),
        "n_params_leaves": n_params_leaves,
        "n_state_leaves": n_state_leaves,
        "n_train_leaves": n_train_leaves,
        "sections": sections,
        "programs": progs,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--set", default="core", choices=["core", "sweep", "longseq", "perf", "all"])
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated variant names")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    vs = variants.get_set(args.set)
    if args.only:
        keep = set(args.only.split(","))
        vs = [v for v in vs if v.name in keep]

    manifest_path = os.path.join(args.out, "manifest.json")
    manifest = {"variants": []}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    existing = {e["name"]: i for i, e in enumerate(manifest["variants"])}
    for v in vs:
        print(f"[aot] lowering {v.name} (heads: {v.cfg.n_dense} dense + "
              f"{v.cfg.n_sparse} {v.cfg.sparse_kind}, T={v.cfg.seq_len}, "
              f"k={v.cfg.k_sel}) ...", flush=True)
        entry = lower_variant(v, args.out)
        if v.name in existing:
            manifest["variants"][existing[v.name]] = entry
        else:
            existing[v.name] = len(manifest["variants"])
            manifest["variants"].append(entry)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(vs)} variants to {args.out}")


if __name__ == "__main__":
    main()
