"""L2: the transformer language model (pre-LN, RoPE, hybrid attention).

The model follows the paper's setup (Sec 3, App C): pre-layer-norm
transformer, RoPE positional encodings, untied input/output embeddings,
4h feed-forward, head dim h', and a hybrid attention layer per block —
``n_dense`` dense (or local) heads plus ``n_sparse`` sparse heads of one of
the kinds {mosa, fixed, routing}.

Everything here is build-time Python: ``aot.py`` lowers the jitted
functions to HLO text once; the Rust coordinator executes them via PJRT.
"""

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .attention import AttnSpec, attention_layer, init_attention, init_attention_state


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    d_model: int = 128
    d_head: int = 16
    d_ff: int = 512
    n_layers: int = 2
    seq_len: int = 128
    n_dense: int = 2
    window: int = 0  # >0 turns the dense heads into local heads
    n_sparse: int = 0
    sparse_kind: str = "none"  # none | mosa | fixed | routing
    k_sel: int = 0
    include_first: bool = True
    use_kernel: bool = True
    rope_theta: float = 10000.0

    def attn_spec(self, seq_len: Optional[int] = None) -> AttnSpec:
        return AttnSpec(
            d_model=self.d_model,
            d_head=self.d_head,
            seq_len=seq_len or self.seq_len,
            n_dense=self.n_dense,
            window=self.window,
            n_sparse=self.n_sparse,
            sparse_kind=self.sparse_kind,
            k_sel=self.k_sel,
            include_first=self.include_first,
            use_kernel=self.use_kernel,
            rope_theta=self.rope_theta,
        )

    def n_params(self) -> int:
        """Exact trainable-parameter count (cross-checked against the Rust
        flops module and, at paper scale, against paper Table 5)."""
        h, d = self.d_model, self.d_head
        attn = self.n_dense * 4 * h * d
        if self.sparse_kind == "mosa":
            attn += self.n_sparse * (4 * h * d + h)
        elif self.sparse_kind == "fixed":
            attn += self.n_sparse * 4 * h * d
        elif self.sparse_kind == "routing":
            attn += self.n_sparse * 3 * h * d
        ffn = 2 * h * self.d_ff + self.d_ff + h
        ln = 3 * 2 * h  # ln1, ln2 per layer contribute 2h each... see below
        per_layer = attn + ffn + 4 * h  # ln1 + ln2 (scale+bias each)
        emb = self.vocab * h
        head = h * self.vocab + self.vocab
        final_ln = 2 * h
        return self.n_layers * per_layer + emb + head + final_ln


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig):
    """Initialise (params, state) pytrees. `state` holds non-gradient
    buffers (routing centroids); it is empty for other variants."""
    h = cfg.d_model
    spec = cfg.attn_spec()
    keys = jax.random.split(key, cfg.n_layers + 3)
    layers = []
    states = []
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[i], 4)
        layers.append(
            {
                "attn": init_attention(lk[0], spec),
                "ln1": {"g": jnp.ones((h,), jnp.float32), "b": jnp.zeros((h,), jnp.float32)},
                "ln2": {"g": jnp.ones((h,), jnp.float32), "b": jnp.zeros((h,), jnp.float32)},
                "ffn": {
                    "w1": (0.02 * jax.random.normal(lk[1], (h, cfg.d_ff))).astype(jnp.float32),
                    "b1": jnp.zeros((cfg.d_ff,), jnp.float32),
                    "w2": (0.02 * jax.random.normal(lk[2], (cfg.d_ff, h))).astype(jnp.float32),
                    "b2": jnp.zeros((h,), jnp.float32),
                },
            }
        )
        st = init_attention_state(lk[3], spec)
        states.append(st)
    params = {
        "emb": (0.02 * jax.random.normal(keys[-3], (cfg.vocab, h))).astype(jnp.float32),
        "layers": layers,
        "lnf": {"g": jnp.ones((h,), jnp.float32), "b": jnp.zeros((h,), jnp.float32)},
        "out": (0.02 * jax.random.normal(keys[-2], (h, cfg.vocab))).astype(jnp.float32),
        "out_b": jnp.zeros((cfg.vocab,), jnp.float32),
    }
    state = {"layers": states}
    return params, state


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _layernorm(p, x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def forward(params, state, tokens, cfg: ModelConfig, seq_len: Optional[int] = None):
    """tokens [B, T] int32 -> logits [B, T, vocab], new_state.

    `seq_len` overrides the attention spec length (downstream-task
    programs run at shorter T with adaptive k, Sec 3.5)."""
    spec = cfg.attn_spec(seq_len)
    x = params["emb"][tokens]  # [B,T,h]
    new_states = []
    for lp, lst in zip(params["layers"], state["layers"]):
        a, nst = attention_layer(lp["attn"], lst, _layernorm(lp["ln1"], x), spec)
        x = x + a
        hdn = _layernorm(lp["ln2"], x)
        hdn = jax.nn.gelu(hdn @ lp["ffn"]["w1"] + lp["ffn"]["b1"])
        x = x + hdn @ lp["ffn"]["w2"] + lp["ffn"]["b2"]
        new_states.append(nst)
    x = _layernorm(params["lnf"], x)
    logits = x @ params["out"] + params["out_b"]
    return logits, {"layers": new_states}


def token_logprobs(params, state, tokens, cfg: ModelConfig, seq_len=None):
    """Per-position log p(tokens[:, t+1] | tokens[:, :t+1]) — the single
    scoring primitive used for both perplexity eval and downstream
    multiple-choice scoring. tokens [B, T] -> lp [B, T-1]."""
    logits, _ = forward(params, state, tokens[:, :-1], cfg, seq_len)
    lp = jax.nn.log_softmax(logits, axis=-1)
    tgt = tokens[:, 1:]
    return jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]


def loss_fn(params, state, tokens, cfg: ModelConfig):
    """Next-token cross-entropy over a [B, T+1] batch window.

    Returns (mean_loss, new_state)."""
    logits, new_state = forward(params, state, tokens[:, :-1], cfg)
    lp = jax.nn.log_softmax(logits, axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll), new_state
