"""The experiment matrix: every model variant we AOT-compile.

Each variant = (trainable preset dims, attention mix, sequence length,
batch size, program set). Head counts for sparse variants always come from
the IsoFLOP solver (flops.solve_sparse_heads) so no sparse model ever
exceeds its dense baseline's attention FLOP budget — exactly the paper's
protocol (Sec 3.2).

Sets:
  core    — dense + one hybrid of each sparse kind at rho=8 (micro scale);
            used by quickstart, integration tests, resource bench.
  sweep   — the IsoFLOP grids behind Table 1 / Fig 3 / Fig 5 / Fig 6 /
            Fig 7 at micro + mini budgets.
  longseq — Fig 4: local+sparse hybrids, k constant, T growing.
  all     — union.
"""

import dataclasses
from typing import Dict, List, Optional

from . import flops
from .model import ModelConfig

# Trainable presets (paper presets are CPU-infeasible; see DESIGN.md §2 —
# Table 4/5 arithmetic is still reproduced exactly at paper scale by the
# flops modules).
PRESETS = {
    "micro": dict(
        vocab=512, d_model=128, d_head=16, d_ff=512, n_layers=2, seq_len=128,
        heads_base=4, batch=8,
    ),
    "mini": dict(
        vocab=512, d_model=192, d_head=24, d_ff=768, n_layers=4, seq_len=192,
        heads_base=6, batch=8,
    ),
    # long-sequence preset: micro dims, growing T (Sec 3.4 analogue)
    "ls": dict(
        vocab=512, d_model=128, d_head=16, d_ff=512, n_layers=2, seq_len=256,
        heads_base=4, batch=2,
    ),
}

N_KEEP_DENSE = 2  # scaled analogue of the paper's 4-of-9 hybrid dense heads
CHUNK_STEPS = 8  # lax.scan steps per train_chunk dispatch
SHORT_T = 64  # downstream-task scoring length (Sec 3.5)


@dataclasses.dataclass(frozen=True)
class DecodeSpec:
    """Serving-program grid for one variant (see compile.decode).

    ``capacity`` is the KV-cache context capacity of the canonical
    ``prefill`` / ``decode_step`` programs; ``extra_batches`` adds
    ``decode_step_b<N>`` programs (batch-scaling bench) and
    ``extra_capacities`` adds ``decode_step_c<C>`` (context-scaling bench,
    decode-only). Static shapes: one lowered program per grid point, the
    standard bucketing of XLA serving.

    Every grid point is also lowered as a paged twin (``prefill_paged``,
    ``decode_step_paged*``, ``decode_step_sample_paged*``) storing the
    cache in fixed-size pages of a shared pool addressed through a
    host-supplied page table. ``page_size`` overrides the per-variant
    default (gcd of the per-kind capacities, capped at 64);
    ``pool_frac`` statically overcommits the capacity-sized (lazy) page
    pools — 0.25 means the device reserves a quarter of the contiguous
    worst case, and admission parks/replays sequences under pressure.
    Bounded kinds (MoSA/fixed k-slots, local rings) are never
    overcommitted: their tiny caches are the paper's Table 2 point."""

    capacity: int = 1024
    extra_batches: tuple = ()
    extra_capacities: tuple = ()
    page_size: Optional[int] = None
    pool_frac: float = 0.25


@dataclasses.dataclass
class Variant:
    name: str
    cfg: ModelConfig
    batch: int
    programs: List[str]  # subset of {init, train, train_chunk, score, score_short, decode}
    group: str  # which experiment family it belongs to
    base_heads: int  # dense-baseline head count the FLOP budget comes from
    decode: Optional[DecodeSpec] = None  # present iff "decode" in programs

    def short_cfg(self) -> ModelConfig:
        """Config for the SHORT_T scoring program with the paper's adaptive
        k = max(floor(T/rho), 2) rule (Sec 3.5)."""
        rho = self.cfg.attn_spec().rho
        k_short = max(SHORT_T // rho, 2) if self.cfg.n_sparse > 0 else 0
        return dataclasses.replace(self.cfg, seq_len=SHORT_T, k_sel=k_short)


def _mk(preset: str, kind: str, rho: int, *, n_keep: Optional[int] = None,
        seq_len: Optional[int] = None, k_const: Optional[int] = None,
        window: int = 0, group: str = "", programs=None, name=None,
        sparse_heads: Optional[int] = None) -> Variant:
    pd = PRESETS[preset]
    t = seq_len or pd["seq_len"]
    base = pd["heads_base"]
    h, hp = pd["d_model"], pd["d_head"]
    if kind == "dense":
        cfg = ModelConfig(
            vocab=pd["vocab"], d_model=h, d_head=hp, d_ff=pd["d_ff"],
            n_layers=pd["n_layers"], seq_len=t, n_dense=base, window=window,
        )
        nm = name or f"{preset}_dense"
    else:
        k = k_const if k_const is not None else max(t // rho, 2)
        nd = N_KEEP_DENSE if n_keep is None else n_keep
        ns = sparse_heads if sparse_heads is not None else flops.solve_sparse_heads(
            h, hp, t, k, base, nd, kind, window
        )
        cfg = ModelConfig(
            vocab=pd["vocab"], d_model=h, d_head=hp, d_ff=pd["d_ff"],
            n_layers=pd["n_layers"], seq_len=t, n_dense=nd, window=window,
            n_sparse=int(ns), sparse_kind=kind, k_sel=k,
        )
        nm = name or f"{preset}_{kind}_r{rho}"
    return Variant(
        name=nm, cfg=cfg, batch=pd["batch"],
        programs=programs or ["train", "score"],
        group=group or preset, base_heads=base,
    )


DECODE_CAPACITY = 1024  # canonical serving context (the paper's Table 2 T)


def core_variants() -> List[Variant]:
    full = ["train", "train_chunk", "score", "score_short", "decode"]
    # micro_dense and micro_mosa_r8 are the BENCH_decode pair: they get the
    # batch- and context-scaling decode grids on top of the canonical
    # C=1024 programs; fixed/routing get the canonical pair only (generate
    # CLI coverage for every head kind).
    bench_decode = DecodeSpec(
        capacity=DECODE_CAPACITY, extra_batches=(1, 32), extra_capacities=(128, 256, 512)
    )
    plain_decode = DecodeSpec(capacity=DECODE_CAPACITY)
    vs = [
        _mk("micro", "dense", 1, programs=full, group="core"),
        _mk("micro", "mosa", 8, programs=full, group="core"),
        _mk("micro", "fixed", 8, programs=["train", "score", "score_short", "decode"], group="core"),
        _mk("micro", "routing", 8, programs=["train", "score", "score_short", "decode"], group="core"),
    ]
    vs[0].decode = bench_decode
    vs[1].decode = bench_decode
    vs[2].decode = plain_decode
    vs[3].decode = plain_decode
    return vs


def sweep_variants() -> List[Variant]:
    vs = []
    # hybrid IsoFLOP grids (Table 1, Fig 3, Fig 6)
    for kind in ("mosa", "fixed", "routing"):
        for rho in (2, 4, 16):  # rho=8 lives in core
            vs.append(_mk("micro", kind, rho, group="sweep"))
    # pure-MoSA grid (Fig 5, Fig 6)
    for rho in (2, 4, 8, 16):
        vs.append(_mk("micro", "mosa", rho, n_keep=0, group="pure",
                      name=f"micro_mosa_r{rho}_pure"))
    # dense-head-count ablation at rho=4 (Fig 7); nd=0 is micro_mosa_r4_pure,
    # nd=2 is micro_mosa_r4, nd=4 = all-dense budget spent
    for nd in (1, 3, 4):
        vs.append(_mk("micro", "mosa", 4, n_keep=nd, group="ablate",
                      name=f"micro_mosa_r4_nd{nd}"))
    # second FLOP budget (mini) for Table 1 scale trend
    vs.append(_mk("mini", "dense", 1, group="sweep"))
    for kind in ("mosa", "fixed", "routing"):
        for rho in (4, 16):
            vs.append(_mk("mini", kind, rho, group="sweep"))
    return vs


def longseq_variants() -> List[Variant]:
    """Fig 4 analogue: local(window)+sparse hybrids, k const, T grows.

    Head counts are fixed at the value solved for the BASE length (256) —
    like the paper's 60-head setup solved at T=1024 — so the relative FLOP
    advantage of MoSA/fixed over routing grows with T."""
    vs = []
    window = 64
    k_const = 32
    base_t = 256
    pd = PRESETS["ls"]
    solved = {
        kind: int(
            flops.solve_sparse_heads(
                pd["d_model"], pd["d_head"], base_t, k_const,
                pd["heads_base"], N_KEEP_DENSE, kind, window,
            )
        )
        for kind in ("mosa", "fixed")
    }
    for t in (256, 512, 1024, 2048):
        for kind in ("mosa", "fixed", "routing"):
            rho = t // k_const
            n_sparse = 2 if kind == "routing" else solved[kind]
            vs.append(
                _mk(
                    "ls", kind, rho, seq_len=t, k_const=k_const, window=window,
                    sparse_heads=n_sparse, group="longseq",
                    name=f"ls{t}_{kind}",
                )
            )
    return vs


def perf_variants() -> List[Variant]:
    """§Perf + Table 2 extras:
    - micro_mosa_r8_nokernel: the same MoSA hybrid lowered through the
      pure-jnp oracle instead of the Pallas kernel (L1 ablation: HLO size,
      measured step time).
    - micro_mosa_r8_match: the *perplexity-matched* configuration of the
      paper's Table 2 — instead of spending the whole FLOP budget on more
      heads (20 at rho=8), keep only 8 sparse heads, targeting the dense
      baseline's quality at a fraction of the compute/KV (Sec 3.3)."""
    v = _mk("micro", "mosa", 8, group="perf", name="micro_mosa_r8_nokernel",
            programs=["train"])
    v.cfg = dataclasses.replace(v.cfg, use_kernel=False)
    m = _mk("micro", "mosa", 8, group="resource", sparse_heads=8,
            name="micro_mosa_r8_match", programs=["train", "score"])
    return [v, m]


def get_set(name: str) -> List[Variant]:
    if name == "core":
        return core_variants()
    if name == "sweep":
        return sweep_variants()
    if name == "longseq":
        return longseq_variants()
    if name == "perf":
        return perf_variants()
    if name == "all":
        return core_variants() + sweep_variants() + longseq_variants() + perf_variants()
    raise ValueError(f"unknown set {name}")
