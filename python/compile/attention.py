"""L2 attention variants: dense / local / MoSA / fixed-sparse / routing.

All variants are expressed through the single L1 kernel
``kernels.attention(q, k, v, qpos, kpos, scale, window)`` — what differs is
*which tokens* each head projects and attends over:

- dense:   all T tokens, qpos = kpos = arange(T)
- local:   all T tokens, sliding window mask
- MoSA:    each head routes sigma(X Wr), expert-choice top-k selects k
           tokens, projections run on the k tokens only (paper Sec 2.2)
- fixed:   the static stride-rho subset [0, rho, 2rho, ...] (Child et al.)
- routing: online-k-means clusters of the shared Q=K projection; per
           cluster the top-k most similar tokens attend to each other
           (Routing Transformer, training-time implementation)

Shapes: x is [B, T, h]; every head group returns [B, T, h] (already summed
over its heads through the per-head output projections W_o, paper Eq. 2/3).
"""

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels import attention, attention_nokernel
from .kernels.ref import ref_rope


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Static configuration of one attention layer (hybrid head mix)."""

    d_model: int
    d_head: int
    seq_len: int
    n_dense: int = 0  # dense or local heads, depending on `window`
    window: int = 0  # 0 => fully causal dense heads; >0 => local heads
    n_sparse: int = 0
    sparse_kind: str = "none"  # none | mosa | fixed | routing
    k_sel: int = 0  # tokens kept per sparse head (k in the paper)
    include_first: bool = True  # StreamingLLM-style: always keep token 0
    use_kernel: bool = True
    rope_theta: float = 10000.0

    @property
    def rho(self) -> int:
        """Sparsity rate rho = T / k (paper Sec 3.2)."""
        return max(1, self.seq_len // max(1, self.k_sel))

    def att(self):
        return attention if self.use_kernel else attention_nokernel


# ---------------------------------------------------------------------------
# parameter initialisation
# ---------------------------------------------------------------------------


def top_k_desc(x, k):
    """(values, indices) of the k largest entries along the last axis.

    `jax.lax.top_k` lowers to a TopK custom-call whose HLO-text attribute
    (`largest=...`) the pinned xla_extension 0.5.1 parser rejects; an
    argsort-based top-k lowers to a plain `sort` instruction instead and
    round-trips through HLO text. Cost is O(T log T) vs O(T log k) — in
    the FLOP accounting both are part of the 2hT routing-overhead term.

    Indices are discrete, so no gradient flows through the selection in
    any case (the router learns through the diag(r) output scaling, paper
    Sec 2.2); stop_gradient on the sort keys makes that explicit and
    avoids the sort-gradient path entirely.
    """
    idx = jnp.argsort(jax.lax.stop_gradient(-x), axis=-1)[..., :k]
    vals = jnp.take_along_axis(x, idx, axis=-1)
    return vals, idx


def _winit(key, shape, scale=0.02):
    return (scale * jax.random.normal(key, shape)).astype(jnp.float32)


def init_attention(key, spec: AttnSpec) -> dict:
    """Initialise one hybrid attention layer's parameters."""
    h, d = spec.d_model, spec.d_head
    p = {}
    keys = jax.random.split(key, 8)
    if spec.n_dense > 0:
        n = spec.n_dense
        p["dense"] = {
            "wq": _winit(keys[0], (n, h, d)),
            "wk": _winit(keys[1], (n, h, d)),
            "wv": _winit(keys[2], (n, h, d)),
            "wo": _winit(keys[3], (n, d, h)),
        }
    if spec.n_sparse > 0 and spec.sparse_kind != "none":
        n = spec.n_sparse
        g = {
            "wq": _winit(keys[4], (n, h, d)),
            "wk": _winit(keys[5], (n, h, d)),
            "wv": _winit(keys[6], (n, h, d)),
            "wo": _winit(keys[7], (n, d, h)),
        }
        if spec.sparse_kind == "mosa":
            g["wr"] = _winit(jax.random.fold_in(key, 101), (n, h))
        if spec.sparse_kind == "routing":
            # shared Q=K projection: drop wk, keep wq as the shared map
            del g["wk"]
        p["sparse"] = g
    return p


def init_attention_state(key, spec: AttnSpec) -> dict:
    """Non-gradient state: routing-attention centroids (EMA k-means)."""
    if spec.sparse_kind == "routing" and spec.n_sparse > 0:
        mu = jax.random.normal(key, (spec.n_sparse, spec.rho, spec.d_head))
        return {"centroids": (mu / (jnp.linalg.norm(mu, axis=-1, keepdims=True) + 1e-6)).astype(jnp.float32)}
    return {}


# ---------------------------------------------------------------------------
# head groups
# ---------------------------------------------------------------------------


def _proj(x, w):
    # x [B,T,h] or [B,n,K,h]; w [n,h,d] -> [B,n,T,d]
    if x.ndim == 3:
        return jnp.einsum("bth,nhd->bntd", x, w)
    return jnp.einsum("bnkh,nhd->bnkd", x, w)


def _dense_heads(p, x, spec: AttnSpec, return_cache=False):
    """Dense (or, with window > 0, local sliding-window) attention heads.

    With ``return_cache`` also returns the per-head roped keys and values
    ([B,n,T,d]) — the prefill program's KV-cache extraction (decode.py)."""
    b, t, h = x.shape
    n = spec.n_dense
    q = _proj(x, p["wq"])  # [B,n,T,d]
    k = _proj(x, p["wk"])
    v = _proj(x, p["wv"])
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, n, t))
    q = ref_rope(q, pos, spec.rope_theta)
    k = ref_rope(k, pos, spec.rope_theta)
    d = spec.d_head
    att = spec.att()(
        q.reshape(b * n, t, d),
        k.reshape(b * n, t, d),
        v.reshape(b * n, t, d),
        pos.reshape(b * n, t),
        pos.reshape(b * n, t),
        None,
        spec.window,
    ).reshape(b, n, t, d)
    y = jnp.einsum("bntd,ndh->bth", att, p["wo"])
    if return_cache:
        return y, {"k": k, "v": v}
    return y


def _gather_tokens(x, idx):
    """x [B,T,h], idx [B,n,K] -> [B,n,K,h] (the X^s of the paper)."""
    b, t, h = x.shape
    _, n, kk = idx.shape
    flat = jnp.take_along_axis(
        x[:, None, :, :], idx[..., None].astype(jnp.int32), axis=2
    )
    return flat  # [B,n,K,h]


def _scatter_heads(y_heads, idx, t):
    """Scatter-add per-head outputs back to original positions (paper: Y).

    y_heads [B,n,K,h], idx [B,n,K] -> [B,T,h]; overlapping selections from
    different heads sum, matching Eq. 3's sum over heads.
    """
    b, n, kk, h = y_heads.shape
    out = jnp.zeros((b, t, h), y_heads.dtype)
    bidx = jnp.arange(b, dtype=jnp.int32)[:, None, None]
    return out.at[jnp.broadcast_to(bidx, idx.shape), idx].add(y_heads)


def _mosa_heads(p, x, spec: AttnSpec, sel_mask=None, return_cache=False):
    """MoSA: expert-choice routed sparse heads (paper Sec 2.2).

    ``sel_mask`` [B,T] bool restricts the expert choice to a valid prompt
    prefix (masked positions get priority -1, below every sigmoid score);
    with an all-true mask the computation is identical to the unmasked
    path. ``return_cache`` also returns the selection (idx, priorities)
    and the selected roped keys / values for the prefill cache."""
    b, t, h = x.shape
    n, d, ksel = spec.n_sparse, spec.d_head, spec.k_sel
    r = jax.nn.sigmoid(jnp.einsum("bth,nh->bnt", x, p["wr"]))  # [B,n,T]
    sel = r
    if spec.include_first:
        # force token 0 into every head's selection (attention-sink trick,
        # Sec 3.2); sigma < 1 < 2 so a score of 2 always wins top-k.
        sel = sel.at[:, :, 0].set(2.0)
    if sel_mask is not None:
        sel = jnp.where(sel_mask[:, None, :], sel, -1.0)
    _, idx = top_k_desc(sel, ksel)  # [B,n,K] indices into T
    idx = jnp.sort(idx, axis=-1).astype(jnp.int32)
    rsel = jnp.take_along_axis(r, idx, axis=-1)  # true router scores
    prisel = jnp.take_along_axis(sel, idx, axis=-1)  # eviction priorities
    xs = _gather_tokens(x, idx)  # [B,n,K,h]
    q = _proj(xs, p["wq"])
    k = _proj(xs, p["wk"])
    v = _proj(xs, p["wv"])
    # RoPE rotates by the *original* positions I (paper "Positional
    # encodings"), and the causal mask inside the kernel compares I too.
    q = ref_rope(q, idx, spec.rope_theta)
    k = ref_rope(k, idx, spec.rope_theta)
    att = spec.att()(
        q.reshape(b * n, ksel, d),
        k.reshape(b * n, ksel, d),
        v.reshape(b * n, ksel, d),
        idx.reshape(b * n, ksel),
        idx.reshape(b * n, ksel),
        None,
        0,
    ).reshape(b, n, ksel, d)
    att = att * rsel[..., None]  # router gradient path (diag(r) A)
    y = jnp.einsum("bnkd,ndh->bnkh", att, p["wo"])
    out = _scatter_heads(y, idx, t)
    if return_cache:
        return out, {"idx": idx, "pri": prisel, "k": k, "v": v}
    return out


def _fixed_heads(p, x, spec: AttnSpec, return_cache=False):
    """Fixed sparse attention: the static stride-rho token subset.

    Special case of MoSA with I = [0, rho, 2rho, ...] and r = 1 (paper
    Sec 3.1). ``return_cache`` also returns the grid indices and the
    selected roped keys / values (prefill cache extraction)."""
    b, t, h = x.shape
    n, d, ksel = spec.n_sparse, spec.d_head, spec.k_sel
    rho = spec.rho
    idx1 = jnp.arange(0, ksel, dtype=jnp.int32) * rho  # [K]
    idx = jnp.broadcast_to(idx1, (b, n, ksel))
    xs = _gather_tokens(x, idx)
    q = _proj(xs, p["wq"])
    k = _proj(xs, p["wk"])
    v = _proj(xs, p["wv"])
    q = ref_rope(q, idx, spec.rope_theta)
    k = ref_rope(k, idx, spec.rope_theta)
    att = spec.att()(
        q.reshape(b * n, ksel, d),
        k.reshape(b * n, ksel, d),
        v.reshape(b * n, ksel, d),
        idx.reshape(b * n, ksel),
        idx.reshape(b * n, ksel),
        None,
        0,
    ).reshape(b, n, ksel, d)
    y = jnp.einsum("bnkd,ndh->bnkh", att, p["wo"])
    out = _scatter_heads(y, idx, t)
    if return_cache:
        return out, {"idx": idx, "k": k, "v": v}
    return out


def _routing_heads(p, x, state, spec: AttnSpec, ema_decay=0.999, return_cache=False):
    """Routing-Transformer attention head group (paper Sec 3.1).

    Shared Q=K projection (wq); keys and centroids L2-normalised; each of
    the rho centroids takes its top-k most similar tokens (training-time
    implementation of online k-means clustering); attention runs inside
    each cluster with the index-aware causal mask; centroids are updated
    with an EMA of their selected (normalised) keys — returned as new
    state, not a gradient.
    """
    b, t, h = x.shape
    n, d, ksel = spec.n_sparse, spec.d_head, spec.k_sel
    rho = spec.rho
    mu = state["centroids"]  # [n, rho, d]
    kq = _proj(x, p["wq"])  # [B,n,T,d]  shared query=key
    v = _proj(x, p["wv"])
    kqn = kq / (jnp.linalg.norm(kq, axis=-1, keepdims=True) + 1e-6)
    mun = mu / (jnp.linalg.norm(mu, axis=-1, keepdims=True) + 1e-6)
    sim = jnp.einsum("bntd,nrd->bnrt", kqn, mun)  # [B,n,rho,T]
    _, idx = top_k_desc(sim, ksel)  # [B,n,rho,K]
    idx = jnp.sort(idx, axis=-1).astype(jnp.int32)

    def take(z):  # z [B,n,T,d] -> [B,n,rho,K,d]
        zi = jnp.broadcast_to(z[:, :, None, :, :], (b, n, rho, t, d))
        return jnp.take_along_axis(zi, idx[..., None], axis=3)

    qs = take(kq)
    vs = take(v)
    qs = ref_rope(qs, idx, spec.rope_theta)
    att = spec.att()(
        qs.reshape(b * n * rho, ksel, d),
        qs.reshape(b * n * rho, ksel, d),
        vs.reshape(b * n * rho, ksel, d),
        idx.reshape(b * n * rho, ksel),
        idx.reshape(b * n * rho, ksel),
        None,
        0,
    ).reshape(b, n, rho, ksel, d)
    y = jnp.einsum("bnrkd,ndh->bnrkh", att, p["wo"])
    out = _scatter_heads(
        y.reshape(b, n * rho, ksel, h), idx.reshape(b, n * rho, ksel), t
    )
    # EMA centroid update from the mean of selected normalised keys.
    sel_keys = take(kqn)  # [B,n,rho,K,d]
    mean_keys = jnp.mean(sel_keys, axis=(0, 3))  # [n,rho,d]
    new_mu = ema_decay * mun + (1.0 - ema_decay) * jax.lax.stop_gradient(mean_keys)
    if return_cache:
        # serving caches the *unroped* shared-QK vectors (rope is recomputed
        # from cached positions at decode) plus the values — 2 vectors/token,
        # matching the kvcache accounting for routing heads.
        return out, {"centroids": new_mu}, {"kq": kq, "v": v}
    return out, {"centroids": new_mu}


# ---------------------------------------------------------------------------
# hybrid layer
# ---------------------------------------------------------------------------


def attention_layer(p, state, x, spec: AttnSpec):
    """Full hybrid attention layer: dense/local heads + one sparse group.

    Returns (y [B,T,h], new_state)."""
    y = jnp.zeros_like(x)
    new_state = state
    if spec.n_dense > 0:
        y = y + _dense_heads(p["dense"], x, spec)
    if spec.n_sparse > 0 and spec.sparse_kind != "none":
        if spec.sparse_kind == "mosa":
            y = y + _mosa_heads(p["sparse"], x, spec)
        elif spec.sparse_kind == "fixed":
            y = y + _fixed_heads(p["sparse"], x, spec)
        elif spec.sparse_kind == "routing":
            ys, new_state = _routing_heads(p["sparse"], x, state, spec)
            y = y + ys
        else:
            raise ValueError(f"unknown sparse kind {spec.sparse_kind}")
    return y, new_state
